"""Flattened-pytree checkpointing to .npz (orbax is unavailable offline).

Stores every leaf under its tree path plus a small JSON metadata blob.
Restoration validates structure + shapes against a template tree (so silent
config drift fails loudly).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.common.tree import flatten_with_paths, unflatten_from_paths

_META_KEY = "__repro_meta__"


def save_checkpoint(path: str, tree: Any, meta: Optional[Dict] = None) -> None:
    flat = flatten_with_paths(tree)
    arrays = {}
    for k, v in flat.items():
        a = np.asarray(v)
        if a.dtype.kind == "V":  # ml_dtypes (bf16/fp8): not npz-serializable
            a = a.astype(np.float32)
        arrays[k] = a
    arrays[_META_KEY] = np.frombuffer(
        json.dumps(meta or {}).encode("utf-8"), dtype=np.uint8
    )
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


def load_checkpoint(path: str, template: Any):
    """Returns (tree_like_template, meta)."""
    with np.load(path) as data:
        meta = json.loads(bytes(data[_META_KEY]).decode("utf-8"))
        flat = {k: data[k] for k in data.files if k != _META_KEY}
    tree = unflatten_from_paths(template, flat)
    # Restore original dtypes from the template (np.savez keeps them, but
    # weak-typed scalars can drift).
    tree = jax.tree.map(
        lambda t, x: x.astype(t.dtype) if hasattr(t, "dtype") else x, template, tree
    )
    return tree, meta


# ---------------------------------------------------------------------------
# Router checkpoints (launch/serve.py --save-router / --restore-router)
# ---------------------------------------------------------------------------

ROUTER_CKPT_KIND = "predictive_router_v1"


def _nest_flat(flat: Dict[str, np.ndarray]) -> Dict:
    """Rebuild nested dicts from ``a/b/c`` leaf paths (template-free).

    Router parameter trees are pure nested dicts of arrays, so the flat
    path encoding is unambiguous — no structure template needed to
    restore one, which is what lets ``--restore-router`` skip offline
    training entirely. Leaves stay numpy: converting here would force
    everything through jax's default dtype policy, silently downcasting
    the float64 cost scaler (and breaking bitwise-identical restores).
    """
    root: Dict = {}
    for key, leaf in flat.items():
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = leaf
    return root


def save_router(path: str, router, pool_names=None) -> None:
    """Persist a trained PredictiveRouter: params + version + scaler meta.

    The cost scaler rides in the array tree (NOT the JSON meta) so its
    dtype survives byte-exactly — ``denormalize_cost`` must reproduce the
    original float32 arithmetic for restored scores to be bitwise equal.
    ``pool_names`` (optional) records which pool members the router's
    member axis refers to, so a restore against a different pool of the
    same size fails loudly instead of silently misrouting.
    """
    tree = {
        "quality": router.quality_params,
        "cost": router.cost_params,
        "model_emb": np.asarray(router.model_emb),
    }
    if router.centroids is not None:
        tree["centroids"] = np.asarray(router.centroids)
    if router.cost_scaler is not None:
        tree["cost_scaler"] = {
            "mu": np.asarray(router.cost_scaler["mu"]),
            "sd": np.asarray(router.cost_scaler["sd"]),
        }
    meta = {
        "kind": ROUTER_CKPT_KIND,
        "quality_kind": router.quality_kind,
        "cost_kind": router.cost_kind,
        "reward": router.reward,
        "version": int(router.version),
    }
    if pool_names is not None:
        meta["pool_names"] = list(pool_names)
    save_checkpoint(path, tree, meta)


def load_router(path: str, expect_pool_names=None):
    """Restore a PredictiveRouter saved by :func:`save_router`.

    ``expect_pool_names``: when given and the checkpoint recorded its pool
    names, the two must match exactly (order included) — the router's
    member axis, cost scaler, and cost ladder are positional, so a
    same-size pool swap would otherwise score every request against the
    wrong models without any error.
    """
    from repro.core.router import PredictiveRouter

    with np.load(path) as data:
        meta = json.loads(bytes(data[_META_KEY]).decode("utf-8"))
        flat = {k: data[k] for k in data.files if k != _META_KEY}
    if meta.get("kind") != ROUTER_CKPT_KIND:
        raise ValueError(
            f"{path!r} is not a router checkpoint "
            f"(kind={meta.get('kind')!r}, want {ROUTER_CKPT_KIND!r})")
    saved_names = meta.get("pool_names")
    if (expect_pool_names is not None and saved_names is not None
            and list(expect_pool_names) != list(saved_names)):
        raise ValueError(
            f"router checkpoint was trained for pool {saved_names}, "
            f"not {list(expect_pool_names)} — member columns are "
            "positional and would misroute silently")
    tree = _nest_flat(flat)
    scaler = tree.get("cost_scaler")
    if scaler is not None:
        scaler = {"mu": np.asarray(scaler["mu"]),
                  "sd": np.asarray(scaler["sd"])}
    as_jnp = lambda t: jax.tree.map(jax.numpy.asarray, t)  # noqa: E731
    return PredictiveRouter(
        quality_kind=meta["quality_kind"],
        cost_kind=meta["cost_kind"],
        quality_params=as_jnp(tree["quality"]),
        cost_params=as_jnp(tree["cost"]),
        model_emb=np.asarray(tree["model_emb"]),
        reward=meta["reward"],
        cost_scaler=scaler,
        version=int(meta["version"]),
        centroids=(np.asarray(tree["centroids"])
                   if "centroids" in tree else None),
    )
