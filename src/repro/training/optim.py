"""Optimizers from scratch (optax is not available offline).

Adam with decoupled weight decay (AdamW) + CosineAnnealingLR, matching the
paper's training recipe (Adam, MSE, CosineAnnealingLR). The optimizer state
is a plain pytree mirroring the params, so it shards with the same
PartitionSpecs (ZeRO-3 by construction under the launch layer's rules).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    # Cosine annealing (eta_min..lr over t_max steps); None = constant lr.
    t_max: Optional[int] = None
    eta_min: float = 0.0
    moment_dtype: Any = jnp.float32   # set bf16 for the factored-memory mode


def cosine_lr(cfg: AdamConfig, step: jax.Array) -> jax.Array:
    if cfg.t_max is None:
        return jnp.float32(cfg.lr)
    t = jnp.minimum(step.astype(jnp.float32), cfg.t_max)
    cos = 0.5 * (1.0 + jnp.cos(math.pi * t / cfg.t_max))
    return cfg.eta_min + (cfg.lr - cfg.eta_min) * cos


def adam_init(cfg: AdamConfig, params: Any) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adam_update(cfg: AdamConfig, grads: Any, state: AdamState, params: Any):
    """One AdamW step. Returns (new_params, new_state)."""
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        delta = lr * m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + lr * cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - delta).astype(p.dtype)
        return p_new, m_new.astype(cfg.moment_dtype), v_new.astype(cfg.moment_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step=step, m=new_m, v=new_v)


def make_train_step(cfg: AdamConfig, loss_fn: Callable):
    """jit-able ``(params, state, *batch) -> (loss, params, state)``."""

    def step(params, state, *batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, *batch)
        params, state = adam_update(cfg, grads, state, params)
        return loss, params, state

    return step
