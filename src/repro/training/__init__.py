"""Training substrate: optimizers, schedules, predictor & LM trainers."""
from repro.training.optim import (
    AdamConfig,
    AdamState,
    adam_init,
    adam_update,
    cosine_lr,
    make_train_step,
)
from repro.training.predictor_trainer import (
    COST_TRAIN,
    QUALITY_TRAIN,
    TrainConfig,
    make_ensemble_predictor_step,
    make_masked_predictor_step,
    make_predictor_step,
    train_dual_predictors,
    train_predictor,
)

__all__ = [
    "AdamConfig", "AdamState", "adam_init", "adam_update", "cosine_lr",
    "make_train_step", "COST_TRAIN", "QUALITY_TRAIN", "TrainConfig",
    "make_ensemble_predictor_step", "make_masked_predictor_step",
    "make_predictor_step", "train_dual_predictors", "train_predictor",
]
