"""Trainer for the routing predictors (paper §5 recipe).

All predictors train with MSE, Adam, CosineAnnealingLR. Paper hypers:
quality predictor lr=1e-3 wd=1e-5; cost predictor lr=1e-4 wd=1e-7; batch
1024; 1000 epochs; 75/5/20 split; model selection on validation loss.
(Epochs are configurable — the synthetic benchmark converges much earlier,
and tests use small counts.)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.predictors import PREDICTORS
from repro.training.optim import AdamConfig, adam_init, make_train_step


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 1e-3
    weight_decay: float = 1e-5
    batch_size: int = 1024
    epochs: int = 200
    seed: int = 0
    eval_every: int = 10


# Paper §5 settings per predictor role.
QUALITY_TRAIN = TrainConfig(lr=1e-3, weight_decay=1e-5)
COST_TRAIN = TrainConfig(lr=1e-4, weight_decay=1e-7)


def train_predictor(
    kind: str,
    q_emb: np.ndarray,            # (N, dq)
    targets: np.ndarray,          # (N, K)
    model_emb: np.ndarray,        # (K, C)
    cfg: TrainConfig,
    val: Optional[Tuple[np.ndarray, np.ndarray]] = None,
) -> Tuple[Dict, Dict[str, float]]:
    """Train one predictor with MSE. Returns (best_params, history)."""
    pred = PREDICTORS[kind]
    n, dq = q_emb.shape
    k = targets.shape[1]
    m = jnp.asarray(model_emb)
    params = pred.init(jax.random.key(cfg.seed), dq, k, model_emb.shape[1])

    def loss_fn(p, qb, tb):
        out = pred.apply(p, qb, m)
        return jnp.mean((out - tb) ** 2)

    steps_per_epoch = max(1, n // cfg.batch_size)
    opt_cfg = AdamConfig(
        lr=cfg.lr, weight_decay=cfg.weight_decay,
        t_max=cfg.epochs * steps_per_epoch,
    )
    state = adam_init(opt_cfg, params)
    step = jax.jit(make_train_step(opt_cfg, loss_fn))

    @jax.jit
    def eval_loss(p, qv, tv):
        return jnp.mean((pred.apply(p, qv, m) - tv) ** 2)

    rng = np.random.default_rng(cfg.seed)
    qj, tj = jnp.asarray(q_emb), jnp.asarray(targets)
    best_params, best_val = params, np.inf
    history = {"train_loss": [], "val_loss": []}
    for epoch in range(cfg.epochs):
        perm = rng.permutation(n)
        ep_loss = 0.0
        for i in range(steps_per_epoch):
            idx = perm[i * cfg.batch_size : (i + 1) * cfg.batch_size]
            if len(idx) == 0:
                continue
            loss, params, state = step(params, state, qj[idx], tj[idx])
            ep_loss += float(loss)
        history["train_loss"].append(ep_loss / steps_per_epoch)
        if val is not None and (epoch % cfg.eval_every == 0 or epoch == cfg.epochs - 1):
            vl = float(eval_loss(params, jnp.asarray(val[0]), jnp.asarray(val[1])))
            history["val_loss"].append(vl)
            if vl < best_val:
                best_val, best_params = vl, jax.tree.map(lambda x: x, params)
    if val is None:
        best_params = params
    return best_params, history


def train_dual_predictors(
    quality_kind: str,
    cost_kind: str,
    q_emb_train: np.ndarray,
    quality_train: np.ndarray,
    cost_train: np.ndarray,
    model_emb: np.ndarray,
    *,
    q_emb_val=None, quality_val=None, cost_val=None,
    epochs: int = 200,
    seed: int = 0,
):
    """Trains the (quality, cost) pair with the paper's per-role hypers.

    Costs are normalized to zero-mean/unit-std per model before regression
    (targets restored at predict time by the caller via the returned scaler).
    """
    qcfg = dataclasses.replace(QUALITY_TRAIN, epochs=epochs, seed=seed)
    ccfg = dataclasses.replace(COST_TRAIN, epochs=epochs, seed=seed + 1)
    qval = (q_emb_val, quality_val) if q_emb_val is not None else None

    mu, sd = cost_train.mean(0), cost_train.std(0) + 1e-9
    cost_norm = (cost_train - mu) / sd
    cval = None
    if q_emb_val is not None and cost_val is not None:
        cval = (q_emb_val, (cost_val - mu) / sd)

    q_params, q_hist = train_predictor(
        quality_kind, q_emb_train, quality_train, model_emb, qcfg, qval)
    c_params, c_hist = train_predictor(
        cost_kind, q_emb_train, cost_norm, model_emb, ccfg, cval)
    scaler = {"mu": mu, "sd": sd}
    return q_params, c_params, scaler, {"quality": q_hist, "cost": c_hist}
