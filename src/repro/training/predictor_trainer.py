"""Trainer for the routing predictors (paper §5 recipe).

All predictors train with MSE, Adam, CosineAnnealingLR. Paper hypers:
quality predictor lr=1e-3 wd=1e-5; cost predictor lr=1e-4 wd=1e-7; batch
1024; 1000 epochs; 75/5/20 split; model selection on validation loss.
(Epochs are configurable — the synthetic benchmark converges much earlier,
and tests use small counts.)

The train step itself is exposed as a reusable, jit-compiled update fn
(:func:`make_predictor_step` for dense (B, K) targets,
:func:`make_masked_predictor_step` for online single-member outcomes) so
the offline epoch loop and the online incremental updater share one
compiled optimizer path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.predictors import ENSEMBLE_KINDS, PREDICTORS
from repro.training.optim import AdamConfig, adam_init, adam_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 1e-3
    weight_decay: float = 1e-5
    batch_size: int = 1024
    epochs: int = 200
    seed: int = 0
    eval_every: int = 10


# Paper §5 settings per predictor role.
QUALITY_TRAIN = TrainConfig(lr=1e-3, weight_decay=1e-5)
COST_TRAIN = TrainConfig(lr=1e-4, weight_decay=1e-7)


@functools.lru_cache(maxsize=64)
def make_predictor_step(kind: str, opt_cfg: AdamConfig):
    """Reusable jit-compiled step for dense (B, K) targets.

    ``step(params, state, q (B,dq), m (K,dm), targets (B,K)) ->
    (loss, params, state)``. Model embeddings are a call argument — not
    closed over — so one compiled step serves both the offline epoch loop
    and any caller that swaps pools, retracing only on new shapes.
    """
    pred = PREDICTORS[kind]

    def loss_fn(p, q, m, t):
        return jnp.mean((pred.apply(p, q, m) - t) ** 2)

    def step(params, state, q, m, t):
        loss, grads = jax.value_and_grad(loss_fn)(params, q, m, t)
        params, state = adam_update(opt_cfg, grads, state, params)
        return loss, params, state

    return jax.jit(step)


@functools.lru_cache(maxsize=64)
def make_ensemble_predictor_step(kind: str, opt_cfg: AdamConfig):
    """Step for deep-ensemble kinds with per-head bootstrap masks.

    ``step(params, state, q (B,dq), m (K,dm), t (B,K), w (B,H)) ->
    (loss, params, state)``. ``w`` holds per-example per-head bootstrap
    weights (Poisson(1) counts — bagging): head ``h`` only sees examples
    with ``w[:, h] > 0`` and sees multiplicities as loss weight, so the
    heads fit *different resamples* of the same data through the shared
    trunk — the disagreement that survives is the epistemic uncertainty
    the cascade escalation policy reads. Same Adam path as
    :func:`make_predictor_step`.
    """
    heads_apply = ENSEMBLE_KINDS[kind]

    def loss_fn(p, q, m, t, w):
        out = heads_apply(p, q, m)                   # (H, B, K)
        err = (out - t[None, :, :]) ** 2
        wm = w.T[:, :, None]                         # (H, B, 1)
        return jnp.sum(err * wm) / (jnp.sum(wm) * t.shape[1] + 1e-9)

    def step(params, state, q, m, t, w):
        loss, grads = jax.value_and_grad(loss_fn)(params, q, m, t, w)
        params, state = adam_update(opt_cfg, grads, state, params)
        return loss, params, state

    return jax.jit(step)


@functools.lru_cache(maxsize=64)
def make_masked_predictor_step(kind: str, opt_cfg: AdamConfig):
    """Step for online outcome tuples: one observed member per example.

    ``step(params, state, q (B,dq), m (K,dm), member (B,) int32,
    target (B,)) -> (loss, params, state)``. MSE is taken only on the
    routed member's prediction — the counterfactual columns get no
    gradient, which is exactly the partial feedback a served router sees.

    Ensemble kinds train through their *mean* here: every head receives
    the same gradient direction, so online outcome updates translate the
    ensemble mean while preserving the bootstrap-established head spread
    (the epistemic-uncertainty signal is not collapsed by serving-time
    feedback).
    """
    pred = PREDICTORS[kind]

    def loss_fn(p, q, m, member, t):
        out = pred.apply(p, q, m)
        chosen = jnp.take_along_axis(out, member[:, None], axis=1)[:, 0]
        return jnp.mean((chosen - t) ** 2)

    def step(params, state, q, m, member, t):
        loss, grads = jax.value_and_grad(loss_fn)(params, q, m, member, t)
        params, state = adam_update(opt_cfg, grads, state, params)
        return loss, params, state

    return jax.jit(step)


def train_predictor(
    kind: str,
    q_emb: np.ndarray,            # (N, dq)
    targets: np.ndarray,          # (N, K)
    model_emb: np.ndarray,        # (K, C)
    cfg: TrainConfig,
    val: Optional[Tuple[np.ndarray, np.ndarray]] = None,
) -> Tuple[Dict, Dict[str, float]]:
    """Train one predictor with MSE. Returns (best_params, history)."""
    pred = PREDICTORS[kind]
    n, dq = q_emb.shape
    k = targets.shape[1]
    m = jnp.asarray(model_emb)
    params = pred.init(jax.random.key(cfg.seed), dq, k, model_emb.shape[1])

    steps_per_epoch = max(1, n // cfg.batch_size)
    opt_cfg = AdamConfig(
        lr=cfg.lr, weight_decay=cfg.weight_decay,
        t_max=cfg.epochs * steps_per_epoch,
    )
    state = adam_init(opt_cfg, params)
    boot = None
    if kind in ENSEMBLE_KINDS:
        # One fixed bootstrap resample per head (Poisson(1) bagging
        # weights), drawn once so every epoch shows each head the same
        # resampled world — the standard deep-ensemble diversity recipe.
        n_heads = int(np.shape(params["bo"])[0])
        boot = jnp.asarray(np.random.default_rng(cfg.seed).poisson(
            1.0, size=(n, n_heads)).astype(np.float32))
        step = make_ensemble_predictor_step(kind, opt_cfg)
    else:
        step = make_predictor_step(kind, opt_cfg)

    @jax.jit
    def eval_loss(p, qv, tv):
        return jnp.mean((pred.apply(p, qv, m) - tv) ** 2)

    rng = np.random.default_rng(cfg.seed)
    qj, tj = jnp.asarray(q_emb), jnp.asarray(targets)
    best_params, best_val = params, np.inf
    history = {"train_loss": [], "val_loss": []}
    for epoch in range(cfg.epochs):
        perm = rng.permutation(n)
        ep_loss = 0.0
        for i in range(steps_per_epoch):
            idx = perm[i * cfg.batch_size : (i + 1) * cfg.batch_size]
            if len(idx) == 0:
                continue
            if boot is not None:
                loss, params, state = step(params, state, qj[idx], m,
                                           tj[idx], boot[idx])
            else:
                loss, params, state = step(params, state, qj[idx], m, tj[idx])
            ep_loss += float(loss)
        history["train_loss"].append(ep_loss / steps_per_epoch)
        if val is not None and (epoch % cfg.eval_every == 0 or epoch == cfg.epochs - 1):
            vl = float(eval_loss(params, jnp.asarray(val[0]), jnp.asarray(val[1])))
            history["val_loss"].append(vl)
            if vl < best_val:
                best_val, best_params = vl, jax.tree.map(lambda x: x, params)
    if val is None:
        best_params = params
    return best_params, history


def train_dual_predictors(
    quality_kind: str,
    cost_kind: str,
    q_emb_train: np.ndarray,
    quality_train: np.ndarray,
    cost_train: np.ndarray,
    model_emb: np.ndarray,
    *,
    q_emb_val=None, quality_val=None, cost_val=None,
    epochs: int = 200,
    seed: int = 0,
):
    """Trains the (quality, cost) pair with the paper's per-role hypers.

    Costs are normalized to zero-mean/unit-std per model before regression
    (targets restored at predict time by the caller via the returned scaler).
    """
    qcfg = dataclasses.replace(QUALITY_TRAIN, epochs=epochs, seed=seed)
    ccfg = dataclasses.replace(COST_TRAIN, epochs=epochs, seed=seed + 1)
    qval = (q_emb_val, quality_val) if q_emb_val is not None else None

    mu, sd = cost_train.mean(0), cost_train.std(0) + 1e-9
    cost_norm = (cost_train - mu) / sd
    cval = None
    if q_emb_val is not None and cost_val is not None:
        cval = (q_emb_val, (cost_val - mu) / sd)

    q_params, q_hist = train_predictor(
        quality_kind, q_emb_train, quality_train, model_emb, qcfg, qval)
    c_params, c_hist = train_predictor(
        cost_kind, q_emb_train, cost_norm, model_emb, ccfg, cval)
    scaler = {"mu": mu, "sd": sd}
    return q_params, c_params, scaler, {"quality": q_hist, "cost": c_hist}
