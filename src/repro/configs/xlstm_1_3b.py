"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks, 1:7 interleave (xLSTM[7:1]).

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304 [arXiv:2405.04517].
d_ff=0: blocks own their projections. Recurrent state => long_500k eligible.
"""
import dataclasses

from repro.configs.base import MLSTM, NONE, SLSTM, ArchConfig, LayerSpec

_PATTERN = (LayerSpec(mixer=SLSTM, ffn=NONE),) + tuple(
    LayerSpec(mixer=MLSTM, ffn=NONE) for _ in range(7)
)

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    source="arXiv:2405.04517",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    xlstm_expand=2,
    pattern=_PATTERN,
    n_repeats=6,
    supports_long_context=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        vocab_size=512,
        pattern=(LayerSpec(mixer=SLSTM, ffn=NONE), LayerSpec(mixer=MLSTM, ffn=NONE)),
        n_repeats=1,
    )
