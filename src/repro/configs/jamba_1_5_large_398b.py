"""jamba-1.5-large-398b [hybrid]: Mamba + attention 1:7, MoE every other layer.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2
[arXiv:2403.19887]. Jamba block = 8 layers with attention at index 3 and MoE
on every odd layer; 72 = 9 x 8. SSM state decode => long_500k eligible.
"""
import dataclasses

from repro.configs.base import ATTN, MAMBA, MLP, MOE, ArchConfig, LayerSpec


def _jamba_pattern(n_per_block: int = 8, attn_idx: int = 3):
    specs = []
    for i in range(n_per_block):
        mixer = ATTN if i == attn_idx else MAMBA
        ffn = MOE if i % 2 == 1 else MLP
        specs.append(LayerSpec(mixer=mixer, ffn=ffn))
    return tuple(specs)


CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    source="arXiv:2403.19887",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    d_ff_expert=24576,
    ssm_d_state=16,
    ssm_d_conv=4,
    ssm_expand=2,
    pattern=_jamba_pattern(),
    n_repeats=9,
    supports_long_context=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=4,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        d_ff_expert=512,
        vocab_size=512,
        n_experts=4,
        top_k=2,
        pattern=_jamba_pattern(n_per_block=4, attn_idx=1),
        n_repeats=1,
    )
