"""Architecture configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig` holding the
exact published dimensions plus a *periodic layer plan*: a base ``pattern`` of
heterogeneous :class:`LayerSpec` blocks repeated ``n_repeats`` times, followed
by an optional ``remainder``. The model stack scans (``jax.lax.scan``) over
the repeats with stacked parameters so HLO size / compile time stay bounded
even for 100-layer models.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# Mixer kinds.
ATTN = "attn"          # causal self attention (full or sliding window)
XATTN = "xattn"        # cross attention to (stubbed) modality embeddings
MAMBA = "mamba"        # selective SSM (Mamba-1)
MLSTM = "mlstm"        # xLSTM matrix-memory LSTM (linear attention family)
SLSTM = "slstm"        # xLSTM scalar-memory LSTM (strictly recurrent)

# FFN kinds.
MLP = "mlp"
MOE = "moe"
NONE = "none"


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One block of the plan: a sequence mixer followed by an optional FFN."""

    mixer: str = ATTN
    ffn: str = MLP
    window: int = 0          # >0: sliding-window self attention (ring KV cache)

    def __post_init__(self):
        assert self.mixer in (ATTN, XATTN, MAMBA, MLSTM, SLSTM), self.mixer
        assert self.ffn in (MLP, MOE, NONE), self.ffn


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                     # dense | moe | ssm | hybrid | vlm | audio
    source: str                        # citation from the assignment table

    # Core transformer dims (published values — do not change).
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0                  # 0 -> d_model // n_heads

    # Attention options.
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    attn_logit_softcap: float = 0.0

    # MoE options.
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # Mamba options.
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0               # 0 -> ceil(d_model / 16)

    # xLSTM options.
    xlstm_expand: int = 2              # mLSTM up-projection factor
    xlstm_ff_factor: float = 2.6667    # sLSTM post-FFN factor (~4/3 * 2)

    # Modality frontend stubs.
    n_frontend_tokens: int = 0         # image patches / audio frames per item
    frontend_dim: int = 0              # raw embedding dim from the stub encoder

    # Layer plan.
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    n_repeats: int = 1
    remainder: Tuple[LayerSpec, ...] = ()

    # Eligibility: sub-quadratic decode for long_500k (see DESIGN.md §5).
    supports_long_context: bool = False

    # Norm epsilon.
    norm_eps: float = 1e-6

    # Max positions (for RoPE tables in serve mode; caches size themselves
    # from the request, this is only a sanity bound).
    max_seq_len: int = 1 << 20

    def __post_init__(self):
        planned = len(self.pattern) * self.n_repeats + len(self.remainder)
        if self.n_layers and planned != self.n_layers:
            raise ValueError(
                f"{self.name}: layer plan covers {planned} layers, "
                f"config says {self.n_layers}"
            )

    # ---- derived quantities -------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so the embedding/LM head shards 16-ways cleanly."""
        return round_up(self.vocab_size, 256)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.ssm_dt_rank or max(1, math.ceil(self.d_model / 16))

    @property
    def xlstm_d_inner(self) -> int:
        return self.xlstm_expand * self.d_model

    @property
    def xlstm_n_heads(self) -> int:
        # xLSTM-1.3B uses 4 heads; reduced smoke variants keep >=1.
        return max(1, min(self.n_kv_heads or 4, self.xlstm_expand * 2))

    def layer_plan(self) -> Tuple[LayerSpec, ...]:
        """The full, flat sequence of layer specs (pattern*n + remainder)."""
        return tuple(self.pattern) * self.n_repeats + tuple(self.remainder)

    def has_mixer(self, kind: str) -> bool:
        return any(s.mixer == kind for s in self.layer_plan())

    def has_ffn(self, kind: str) -> bool:
        return any(s.ffn == kind for s in self.layer_plan())

    # ---- parameter count estimate (for cost model + docs) -------------------

    def param_count(self) -> int:
        """Analytic parameter count of the full model."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.padded_vocab * d          # tied embedding? keep separate head
        total += self.padded_vocab * d         # lm head
        for spec in self.layer_plan():
            total += 2 * d                     # pre-mixer + pre-ffn norms
            if spec.mixer in (ATTN, XATTN):
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                total += q + kv + o
                if self.qkv_bias:
                    total += (self.n_heads + 2 * self.n_kv_heads) * hd
                if spec.mixer == XATTN and self.frontend_dim:
                    total += self.frontend_dim * d  # modality projector
            elif spec.mixer == MAMBA:
                di, ds, dtr = self.ssm_d_inner, self.ssm_d_state, self.resolved_dt_rank
                total += d * 2 * di            # in_proj (x, z)
                total += di * self.ssm_d_conv  # depthwise conv
                total += di * (dtr + 2 * ds)   # x_proj
                total += dtr * di + di         # dt_proj
                total += di * ds + di          # A_log, D
                total += di * d                # out_proj
            elif spec.mixer == MLSTM:
                di = self.xlstm_d_inner
                nh = self.xlstm_n_heads
                total += d * 2 * di            # up projection (x, z)
                total += 3 * di * (di // nh)   # block-diag q,k,v per head
                total += 2 * di * nh           # i,f gate projections
                total += di * d                # down projection
            elif spec.mixer == SLSTM:
                nh = self.xlstm_n_heads
                hd_s = d // nh
                total += 4 * d * d             # W_{z,i,f,o}
                total += 4 * nh * hd_s * hd_s  # block-diag recurrent R
                total += 4 * d                 # biases
                f = self.xlstm_ff_factor
                total += int(2 * d * d * f)    # gated FFN up/down
            if spec.ffn == MLP and self.d_ff:
                total += 3 * d * self.d_ff     # gate, up, down (SwiGLU)
            elif spec.ffn == MOE:
                e, fe = self.n_experts, self.d_ff_expert or self.d_ff
                total += d * e                 # router
                total += e * 3 * d * fe
                total += self.n_shared_experts * 3 * d * fe
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if not self.has_ffn(MOE):
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        e, k, fe = self.n_experts, self.top_k, self.d_ff_expert or self.d_ff
        n_moe = sum(1 for s in self.layer_plan() if s.ffn == MOE)
        total -= n_moe * (e - k) * 3 * d * fe
        return int(total)
