"""llama4-maverick-400b-a17b [moe]: 128 experts top-1 + shared expert.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1
[hf:meta-llama/Llama-4-Scout-17B-16E family]. MoE on every other layer
(interleave-moe-layer-step=2), each MoE layer adds a shared expert.
"early fusion" multimodality: the image tokenizer is the carve-out stub —
the backbone consumes fused text/image token ids directly.
"""
import dataclasses

from repro.configs.base import ATTN, MLP, MOE, ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    n_experts=128,
    top_k=1,
    d_ff_expert=8192,
    n_shared_experts=1,
    rope_theta=500_000.0,
    pattern=(LayerSpec(mixer=ATTN, ffn=MLP), LayerSpec(mixer=ATTN, ffn=MOE)),
    n_repeats=24,
    supports_long_context=False,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=512,
        d_ff_expert=512,
        vocab_size=512,
        n_experts=4,
        top_k=1,
        n_repeats=1,
    )
