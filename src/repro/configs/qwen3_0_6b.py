"""qwen3-0.6b [dense]: qk_norm, GQA.

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936 [hf:Qwen/Qwen3-8B family].
"""
import dataclasses

from repro.configs.base import ATTN, MLP, ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen3-0.6b",
    arch_type="dense",
    source="hf:Qwen/Qwen3-8B",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    pattern=(LayerSpec(mixer=ATTN, ffn=MLP),),
    n_repeats=28,
    supports_long_context=False,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        n_repeats=2,
    )
