"""Assigned-architecture registry.

``get_config(name)`` returns the full published config; ``get_smoke_config``
returns the reduced same-family variant used by CPU smoke tests
(<=2 pattern repeats, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig

ARCH_IDS: List[str] = [
    "musicgen-large",
    "xlstm-1.3b",
    "granite-moe-1b-a400m",
    "jamba-1.5-large-398b",
    "gemma3-27b",
    "qwen1.5-4b",
    "qwen3-0.6b",
    "llama4-maverick-400b-a17b",
    "llama-3.2-vision-90b",
    "granite-3-8b",
]

_MODULES: Dict[str, str] = {
    "musicgen-large": "musicgen_large",
    "xlstm-1.3b": "xlstm_1_3b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "gemma3-27b": "gemma3_27b",
    "qwen1.5-4b": "qwen1_5_4b",
    "qwen3-0.6b": "qwen3_0_6b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "granite-3-8b": "granite_3_8b",
}


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.smoke_config()


def all_configs() -> Dict[str, ArchConfig]:
    return {n: get_config(n) for n in ARCH_IDS}


def replace(cfg: ArchConfig, **kw) -> ArchConfig:
    return dataclasses.replace(cfg, **kw)
