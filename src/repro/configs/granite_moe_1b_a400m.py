"""granite-moe-1b-a400m [moe]: 32 experts, top-8.

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]. d_ff=512 is the per-expert FF.
"""
import dataclasses

from repro.configs.base import ATTN, MOE, ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    n_experts=32,
    top_k=8,
    d_ff_expert=512,
    pattern=(LayerSpec(mixer=ATTN, ffn=MOE),),
    n_repeats=24,
    supports_long_context=False,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        d_ff_expert=128,
        vocab_size=512,
        n_experts=4,
        top_k=2,
        n_repeats=2,
    )
