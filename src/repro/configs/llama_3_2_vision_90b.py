"""llama-3.2-vision-90b [vlm]: cross-attention image layers.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[hf:meta-llama/Llama-3.2-11B-Vision family]: 80 self-attention decoder
layers + 20 interleaved cross-attention layers (1 per 4 self layers).

The ViT vision encoder is the carve-out stub: ``input_specs`` provides
precomputed patch embeddings (n_frontend_tokens x frontend_dim); the learned
projector + cross-attention layers that consume them are real.
"""
import dataclasses

from repro.configs.base import ATTN, MLP, XATTN, ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    n_frontend_tokens=1601,        # 1 tile x (40x40 patches + 1 cls)
    frontend_dim=1280,             # ViT-H width
    pattern=(LayerSpec(mixer=ATTN, ffn=MLP),) * 4
    + (LayerSpec(mixer=XATTN, ffn=MLP),),
    n_repeats=20,
    supports_long_context=False,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        n_frontend_tokens=16,
        frontend_dim=64,
        pattern=(LayerSpec(mixer=ATTN), LayerSpec(mixer=XATTN)),
        n_repeats=1,
    )
