"""Assigned input shapes + ShapeDtypeStruct input specs for the dry-run.

Shapes (assigned):
    train_4k     seq_len=4096    global_batch=256   (training)
    prefill_32k  seq_len=32768   global_batch=32    (inference prefill)
    decode_32k   seq_len=32768   global_batch=128   (inference decode: ONE new
                                                     token + seq_len KV cache)
    long_500k    seq_len=524288  global_batch=1     (long-context decode;
                                                     sub-quadratic archs only)

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, no device allocation. Decode
shapes include the abstract cache tree.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm as lm_mod


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}

SHAPE_IDS = list(SHAPES)


def shape_applicable(cfg: ArchConfig, shape: ShapeCfg) -> bool:
    """long_500k only runs on sub-quadratic archs (DESIGN.md §5)."""
    if shape.name == "long_500k":
        return cfg.supports_long_context
    return True


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(
    cfg: ArchConfig,
    shape: ShapeCfg,
    cache_dtype=jnp.bfloat16,
    batch_override: Optional[int] = None,
) -> Dict:
    """Abstract inputs for (architecture x shape). Keys match the step fns."""
    b = batch_override or shape.global_batch
    s = shape.seq_len
    media_spec = None
    if cfg.arch_type == "vlm" and cfg.n_frontend_tokens:
        media_spec = _sds((b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.bfloat16)

    if shape.kind == "train":
        specs = {
            "tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
        }
        if media_spec is not None:
            specs["media"] = media_spec
        return specs

    if shape.kind == "prefill":
        specs = {
            "tokens": _sds((b, s), jnp.int32),
            "caches": lm_mod.abstract_caches(cfg, b, s, cache_dtype),
        }
        if media_spec is not None:
            specs["media"] = media_spec
        return specs

    # decode: ONE new token against a seq_len cache.
    return {
        "token": _sds((b, 1), jnp.int32),
        "caches": lm_mod.abstract_caches(cfg, b, s, cache_dtype),
        "pos": _sds((), jnp.int32),
    }
