"""qwen1.5-4b [dense]: QKV bias.

40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936 [hf:Qwen/Qwen1.5-0.5B].
"""
import dataclasses

from repro.configs.base import ATTN, MLP, ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    arch_type="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    pattern=(LayerSpec(mixer=ATTN, ffn=MLP),),
    n_repeats=40,
    supports_long_context=False,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        n_repeats=2,
    )
