"""granite-3-8b [dense]: GQA.

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155
[hf:ibm-granite/granite-3.0-2b-base family].
"""
import dataclasses

from repro.configs.base import ATTN, MLP, ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="granite-3-8b",
    arch_type="dense",
    source="hf:ibm-granite/granite-3.0-2b-base",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    pattern=(LayerSpec(mixer=ATTN, ffn=MLP),),
    n_repeats=40,
    supports_long_context=False,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        n_repeats=2,
    )
