"""musicgen-large [audio]: decoder-only transformer over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048 [arXiv:2306.05284].
The EnCodec audio codec (mel/conv frontend) is the carve-out stub: the
language backbone consumes codec *token ids* directly — ``input_specs``
provides int32 codebook tokens of the published vocab.
"""
import dataclasses

from repro.configs.base import ATTN, MLP, ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="musicgen-large",
    arch_type="audio",
    source="arXiv:2306.05284",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    pattern=(LayerSpec(mixer=ATTN, ffn=MLP),),
    n_repeats=48,
    supports_long_context=False,   # pure full attention
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        n_repeats=2,
    )
