"""gemma3-27b [dense]: 5:1 local:global attention, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144
[hf:google/gemma-3-1b-pt family]. Locals use a 1024-token sliding window
(ring KV cache), globals use full attention with a higher RoPE base.
62 = 10 x (5 local + 1 global) + 2 local remainder.

long_500k eligibility: 52/62 layers hold only a 1024-slot ring cache; the
10 global layers keep the full 500k KV — decode stays O(S) per token
(memory-bound, sub-quadratic), so the shape runs (see DESIGN.md §5).
"""
import dataclasses

from repro.configs.base import ATTN, MLP, ArchConfig, LayerSpec

LOCAL_WINDOW = 1024

_LOCAL = LayerSpec(mixer=ATTN, ffn=MLP, window=LOCAL_WINDOW)
_GLOBAL = LayerSpec(mixer=ATTN, ffn=MLP, window=0)

CONFIG = ArchConfig(
    name="gemma3-27b",
    arch_type="dense",
    source="hf:google/gemma-3-1b-pt",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    qk_norm=True,                     # gemma3 applies qk-norm
    rope_theta=1_000_000.0,           # global-layer rope base
    pattern=(_LOCAL,) * 5 + (_GLOBAL,),
    n_repeats=10,
    remainder=(_LOCAL, _LOCAL),
    supports_long_context=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=3,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        pattern=(
            dataclasses.replace(_LOCAL, window=8),
            dataclasses.replace(_LOCAL, window=8),
            _GLOBAL,
        ),
        n_repeats=1,
        remainder=(),
    )
