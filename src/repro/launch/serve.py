"""Routed serving driver.

    PYTHONPATH=src python -m repro.launch.serve --pool qwen3-0.6b,xlstm-1.3b \
        --requests 32 --lam 1.0

Builds reduced pool members on CPU (full configs require the production
mesh), trains the attention router on synthetic RouterBench traffic mapped
onto the pool, then serves a batch of requests end to end.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import build_model_embeddings
from repro.core.router import PredictiveRouter
from repro.data import generate
from repro.models import lm as lm_mod
from repro.serving import PoolMember, RoutedEngine, arch_cost_rate
from repro.training import train_dual_predictors


def build_pool(names, seed: int = 0, vocab: int = 512):
    """Reduced configs execute on CPU; cost rates come from the FULL
    configs (the economics the router must learn are those of the real
    architectures, not of the smoke-test stand-ins)."""
    from repro.configs import get_config

    members = []
    for i, name in enumerate(names):
        cfg = get_smoke_config(name)
        params = lm_mod.init_lm(jax.random.key(seed + i), cfg)
        members.append(PoolMember(
            name=name, cfg=cfg, params=params,
            quality_profile=None,
            cost_rate=arch_cost_rate(get_config(name)),
        ))
    return members


def synthetic_pool_traffic(pool, n: int = 1200, seed: int = 0):
    """Map synthetic RouterBench quality columns onto the pool members by
    cost order (cheapest member <- cheapest API model, etc.)."""
    data = generate(n, seed=seed)
    api_cost_order = np.argsort(data.cost.mean(0))          # cheap -> pricey
    member_rank = np.argsort(np.argsort([m.cost_rate for m in pool]))
    k_api, p = len(api_cost_order), len(pool)
    cols = [
        int(api_cost_order[int(round(member_rank[i] * (k_api - 1) / max(p - 1, 1)))])
        for i in range(p)
    ]
    quality = data.quality[:, cols]                          # pool order
    cost = np.stack([np.full(n, m.cost_rate) for m in pool], axis=1)
    return data, quality, cost


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool", default="qwen3-0.6b,granite-moe-1b-a400m,granite-3-8b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--epochs", type=int, default=120)
    args = ap.parse_args()

    names = args.pool.split(",")
    pool = build_pool(names)
    data, quality, cost = synthetic_pool_traffic(pool)
    tr, va, te = data.split()

    memb, _ = build_model_embeddings(data.emb[tr], quality[tr])
    qp, cp, scaler, _ = train_dual_predictors(
        "attn", "attn", data.emb[tr], quality[tr], cost[tr], memb,
        q_emb_val=data.emb[va], quality_val=quality[va], cost_val=cost[va],
        epochs=args.epochs,
    )
    router = PredictiveRouter("attn", "attn", qp, cp, memb,
                              reward="R2", cost_scaler=scaler)
    engine = RoutedEngine(router=router, pool=pool, lam=args.lam)

    texts = [data.texts[i] for i in te[: args.requests]]
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(
            0, min(m.cfg.vocab_size for m in pool), size=(len(texts), 16)
        ),
        jnp.int32,
    )
    result = engine.serve(texts, prompts, max_new=4)
    print("routed counts per member:",
          dict(zip(names, result["per_member_counts"].tolist())))
    print(f"total cost ${result['total_cost']:.6f}  "
          f"latency {result['latency_s']:.2f}s")


if __name__ == "__main__":
    main()
