"""Streaming routed-serving driver: simulated open-loop traffic end to end.

    PYTHONPATH=src python -m repro.launch.serve --trace poisson --requests 200
    PYTHONPATH=src python -m repro.launch.serve --trace bursty --requests 200 \
        --budget 0.02 --budget-window 0.5 --lam 1.0
    PYTHONPATH=src python -m repro.launch.serve --trace drift --requests 400 \
        --workers 4 --online --crash-at 0.1 --rejoin-at 0.3
    PYTHONPATH=src python -m repro.launch.serve --trace poisson --requests 200 \
        --cascade --max-legs 3 --budget 0.02

``--cascade`` trains the deep-ensemble quality head and runs multi-leg
escalation (repro.cascade): answers that look inadequate against the next
cost-ladder rung's expected marginal reward are re-admitted at elevated
priority, every leg is charged to the budget ledger, and telemetry splits
quality/cost/latency by leg. ``--semcache`` adds a semantic answer cache
as rung 0 of that ladder: near-duplicate queries (see ``--trace neardup``)
are answered from cache when the rung-0 stop-vs-escalate decision — the
same expected-marginal-reward math as the cascade — says the cached
answer's risk-adjusted quality beats paying for generation. ``--save-router`` / ``--restore-router``
persist the trained router (params + version + cost-scaler meta); restored
routers score bitwise-identically.

Builds reduced pool members on CPU (full configs require the production
mesh), trains the attention router on synthetic RouterBench traffic mapped
onto the pool, then replays a simulated traffic scenario (poisson / bursty /
drift) through the admission queue + continuous micro-batching scheduler,
reporting per-member counts, spend vs. budget, and latency percentiles.

``--workers N`` (N > 1) runs the multi-worker serving plane instead of the
single scheduler: N workers (simulated multi-host over local state, each
with its own engine replica, queue, and virtual clock) share the pool and —
with ``--budget`` — one global SharedBudgetLedger; with ``--online`` the
workers run follower adapters and the coordinator periodically merges their
replay buffers onto the leader, runs the bounded update steps there, and
broadcasts the versioned router to every worker. ``--crash-at``/
``--rejoin-at`` inject a worker crash-and-rejoin scenario;
``--feedback-delay`` routes quality feedback through the staged
delayed-outcome path.

Every random path — pool init, synthetic traffic, router training, the
trace arrival/content sampling, and the prompt token RNG — derives from
``--seed``, so runs are reproducible end to end.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import build_model_embeddings
from repro.core.router import PredictiveRouter
from repro.data import generate
from repro.models import lm as lm_mod
from repro.serving import (
    TRACE_KINDS,
    BudgetGovernor,
    MicroBatchScheduler,
    PoolMember,
    RoutedEngine,
    SchedulerConfig,
    SemanticCache,
    TraceConfig,
    arch_cost_rate,
    calibrate_radius,
    default_service_model,
    make_trace,
)
from repro.training import train_dual_predictors


def build_pool(names, seed: int = 0):
    """Reduced configs execute on CPU; cost rates come from the FULL
    configs (the economics the router must learn are those of the real
    architectures, not of the smoke-test stand-ins)."""
    from repro.configs import get_config

    members = []
    for i, name in enumerate(names):
        cfg = get_smoke_config(name)
        params = lm_mod.init_lm(jax.random.key(seed + i), cfg)
        members.append(PoolMember(
            name=name, cfg=cfg, params=params,
            quality_profile=None,
            cost_rate=arch_cost_rate(get_config(name)),
        ))
    return members


def pool_quality_columns(pool, data) -> list:
    """RouterBench quality columns for the pool members, matched by cost
    order (cheapest member <- cheapest API model, etc.)."""
    api_cost_order = np.argsort(data.cost.mean(0))          # cheap -> pricey
    member_rank = np.argsort(np.argsort([m.cost_rate for m in pool]))
    k_api, p = len(api_cost_order), len(pool)
    return [
        int(api_cost_order[int(round(member_rank[i] * (k_api - 1) / max(p - 1, 1)))])
        for i in range(p)
    ]


def synthetic_pool_traffic(pool, n: int = 1200, seed: int = 0):
    """Map synthetic RouterBench quality columns onto the pool members."""
    data = generate(n, seed=seed)
    quality = data.quality[:, pool_quality_columns(pool, data)]  # pool order
    cost = np.stack([np.full(n, m.cost_rate) for m in pool], axis=1)
    return data, quality, cost


def build_routed_engine(names, *, seed: int = 0, epochs: int = 120,
                        lam: float = 1.0, n_traffic: int = 1200,
                        use_pallas: bool = False, quality_kind: str = "attn",
                        restore_router: str = None):
    """Pool + trained router + engine, all seeded. Returns (engine, data, te).

    ``quality_kind="attn-ens"`` trains the deep-ensemble quality head (the
    cascade path's uncertainty source). ``restore_router`` skips offline
    predictor training entirely and loads a checkpoint saved by
    ``--save-router`` instead (the pool and traffic corpus are still built
    — they are the serving substrate, not router state).
    """
    pool = build_pool(names, seed=seed)
    data, quality, cost = synthetic_pool_traffic(pool, n=n_traffic, seed=seed)
    tr, va, te = data.split(seed=seed)
    if restore_router is not None:
        from repro.checkpoint import load_router

        router = load_router(restore_router, expect_pool_names=names)
        if router.n_members != len(pool):
            raise ValueError(
                f"checkpoint pool size {router.n_members} != "
                f"serving pool size {len(pool)}")
    else:
        memb, centers = build_model_embeddings(data.emb[tr], quality[tr],
                                               seed=seed)
        qp, cp, scaler, _ = train_dual_predictors(
            quality_kind, "attn", data.emb[tr], quality[tr], cost[tr], memb,
            q_emb_val=data.emb[va], quality_val=quality[va],
            cost_val=cost[va], epochs=epochs, seed=seed,
        )
        # Centroids ride on the router so online hot-added members can be
        # embedded per-cluster from live outcomes (repro.online.membership).
        router = PredictiveRouter(quality_kind, "attn", qp, cp, memb,
                                  reward="R2", cost_scaler=scaler,
                                  centroids=centers)
    engine = RoutedEngine(router=router, pool=pool, lam=lam,
                          use_pallas=use_pallas)
    return engine, data, te


def _streaming_requested(args) -> bool:
    return (args.scrape_every is not None or args.trace_sample is not None
            or args.trace_cap is not None or args.obs_dir is not None)


def _setup_obs(args):
    """(recorder, registry, profiler, flusher) from the obs flags.

    All default to None — the runtime's tracer branches then cost nothing.
    Streaming mode (any of ``--scrape-every/--trace-sample/--trace-cap/
    --obs-dir``) builds the recorder with the sampler/cap installed and an
    :class:`ObsFlusher` over the segment directory; with no
    ``--scrape-every`` the flusher still applies sampling, in one
    final-only flush. ``--trace-profile`` additionally installs the
    kernel-dispatch profiler globally (removed again by :func:`_save_obs`).
    """
    recorder = registry = profiler = flusher = None
    streaming = _streaming_requested(args)
    label = f"serve-{args.trace}-seed{args.seed}"
    if args.trace_out or args.trace_profile or streaming:
        from repro.obs import TraceRecorder, TraceSampler

        sampler = None
        if args.trace_sample is not None:
            sampler = TraceSampler(args.trace_sample, seed=args.seed,
                                   head=args.trace_head)
        recorder = TraceRecorder(
            label=label, sampler=sampler,
            max_buffered_per_worker=args.trace_cap)
    if args.metrics_out or streaming:
        from repro.obs import MetricsRegistry

        registry = MetricsRegistry()
    if streaming:
        from repro.obs import ObsFlusher

        obs_dir = args.obs_dir or f"obs_{args.trace}_seed{args.seed}"
        args.obs_dir = obs_dir
        flusher = ObsFlusher(
            obs_dir, recorder=recorder, registry=registry,
            scrape_every_s=args.scrape_every, label=label,
            include_wall=args.trace_profile,
            deterministic_metrics=not args.trace_profile)
    if args.trace_profile:
        from repro.kernels import ops as kops
        from repro.obs import KernelProfiler

        profiler = KernelProfiler(tracer=recorder)
        kops.set_kernel_profiler(profiler)
    return recorder, registry, profiler, flusher


def _save_obs(args, recorder, registry, profiler, flusher=None,
              now: float = 0.0):
    """Write the observability artifacts and uninstall the profiler.

    ``now`` is the run's final virtual time — it stamps the flusher's
    last segment and manifest. In streaming mode ``--trace-out`` becomes
    the concatenation of the rotated segments (still one valid,
    replay-stable Chrome trace — minus sampled-out request trees).
    """
    if profiler is not None:
        from repro.kernels import ops as kops

        kops.set_kernel_profiler(None)
        print(profiler.report())
        if registry is not None:
            profiler.register_metrics(registry)
    if flusher is not None:
        flusher.finalize(now)
        stats = recorder.drop_stats
        print(f"obs segments written to {args.obs_dir} "
              f"({flusher.seq} flushes, peak {recorder.peak_buffered} "
              f"buffered events, {stats['requests_sampled_out']} trees "
              f"sampled out, {stats['requests_shed']} shed)")
        if args.trace_out:
            import json as _json

            from repro.obs import concat_dir

            doc = concat_dir(args.obs_dir)
            with open(args.trace_out, "w") as f:
                f.write(_json.dumps(doc, sort_keys=True,
                                    separators=(",", ":")))
            print(f"concatenated trace written to {args.trace_out}")
    elif recorder is not None and args.trace_out:
        recorder.save(args.trace_out, include_wall=args.trace_profile)
        print(f"trace written to {args.trace_out} "
              f"({recorder.n_events} events)")
    if registry is not None and args.metrics_out:
        if args.metrics_out.endswith((".prom", ".txt")):
            registry.save_prometheus(args.metrics_out)
        else:
            # Deterministic snapshot unless the operator opted wall-clock
            # data in — replays of a seeded run then produce identical
            # bytes, same contract as the trace.
            registry.save(args.metrics_out,
                          deterministic=not args.trace_profile)
        print(f"metrics snapshot written to {args.metrics_out} "
              f"({len(registry)} series)")


def _make_slo(args, tracer=None):
    """SLO tracker from the --slo-* flags (None when none are set)."""
    from repro.obs import build_slo_tracker

    return build_slo_tracker(
        tracer=tracer, p95_target_s=args.slo_p95,
        miss_rate_budget=args.slo_miss_rate,
        quality_floor=args.slo_quality_floor,
        spend_per_window=args.slo_spend, window_s=args.slo_window)


def _print_slo(slo, now: float) -> None:
    if slo is None:
        return
    firing = slo.firing()
    burns = {name: f"{b['long']:.2f}x"
             for name, b in slo.burn_rates(now).items()}
    print(f"slo: {slo.alerts_total} alert transitions  "
          f"firing {firing if firing else 'none'}  long-window burn "
          + "  ".join(f"{k}={v}" for k, v in burns.items()))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pool", default="qwen3-0.6b,granite-moe-1b-a400m,granite-3-8b")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--trace", default="poisson", choices=TRACE_KINDS)
    ap.add_argument("--rate", type=float, default=400.0,
                    help="mean arrivals per virtual second")
    ap.add_argument("--lam", type=float, default=1.0,
                    help="nominal willingness-to-pay")
    ap.add_argument("--budget", type=float, default=0.0,
                    help="$ budget per rolling window (0 disables governor)")
    ap.add_argument("--budget-window", type=float, default=0.5,
                    help="governor window, virtual seconds")
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds pool init, traffic, training, trace and prompts")
    ap.add_argument("--epochs", type=int, default=80)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait", type=float, default=0.05)
    ap.add_argument("--score-batch", type=int, default=64)
    ap.add_argument("--queue-capacity", type=int, default=512)
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline, virtual seconds after arrival")
    ap.add_argument("--pallas", action="store_true",
                    help="score through the fused Pallas router_xattn path")
    ap.add_argument("--wall-time", action="store_true",
                    help="advance the virtual clock by measured wall time "
                         "instead of the deterministic service model")
    ap.add_argument("--online", action="store_true",
                    help="online adaptation: replay-buffered outcome "
                         "feedback, drift detection, exploration, and "
                         "incremental router updates during serving")
    ap.add_argument("--cascade", action="store_true",
                    help="cascade routing: train the deep-ensemble quality "
                         "head and escalate inadequate answers up the cost "
                         "ladder (multi-leg requests, cumulative-cost "
                         "budget accounting)")
    ap.add_argument("--max-legs", type=int, default=3,
                    help="cascade: max legs per request")
    ap.add_argument("--cascade-beta", type=float, default=1.0,
                    help="cascade: optimism width on untried rungs "
                         "(x ensemble std)")
    ap.add_argument("--cascade-margin", type=float, default=0.0,
                    help="cascade: required expected marginal reward to "
                         "escalate")
    ap.add_argument("--cascade-min-headroom", type=float, default=0.0,
                    help="cascade: budget headroom in [0,1] below which "
                         "escalation is blocked (0 disables the gate; "
                         "needs --budget to have any effect)")
    ap.add_argument("--semcache", action="store_true",
                    help="semantic answer cache as cascade rung 0: "
                         "embedding-keyed reuse of finalized answers for "
                         "near-duplicate queries, stop-vs-escalate decided "
                         "by the same expected-marginal-reward policy as "
                         "the cascade ladder")
    ap.add_argument("--cache-radius", type=float, default=None,
                    help="semcache: L2 match radius in embedding space "
                         "(default: calibrated from the training split's "
                         "nearest-neighbour distance quantile)")
    ap.add_argument("--cache-cap", type=int, default=256,
                    help="semcache: max entries (LRU eviction past it)")
    ap.add_argument("--save-router", default=None, metavar="PATH",
                    help="persist the trained router (params + version + "
                         "cost-scaler meta) after offline training")
    ap.add_argument("--restore-router", default=None, metavar="PATH",
                    help="load a --save-router checkpoint instead of "
                         "training (restored scores are bitwise-identical)")
    ap.add_argument("--refresh-established", action="store_true",
                    help="online: EMA outcome-driven embedding refresh for "
                         "graduated (established) pool members under drift")
    ap.add_argument("--online-update-every", type=int, default=32,
                    help="outcomes between scheduled incremental updates")
    ap.add_argument("--epsilon", type=float, default=0.05,
                    help="exploration rate at full budget headroom")
    ap.add_argument("--feedback-delay", type=float, default=0.0,
                    help="virtual seconds between completion and quality "
                         "feedback (staged delayed-outcome path; 0 = "
                         "feedback at completion time)")
    ap.add_argument("--workers", type=int, default=1,
                    help="N>1 runs the multi-worker serving plane "
                         "(repro.distributed) with leader/follower sync")
    ap.add_argument("--sync-every", type=float, default=0.05,
                    help="virtual seconds between replay-merge/broadcast "
                         "sync rounds (multi-worker only)")
    ap.add_argument("--crash-at", type=float, default=None,
                    help="crash --crash-worker at this virtual time "
                         "(multi-worker only)")
    ap.add_argument("--rejoin-at", type=float, default=None,
                    help="rejoin the crashed worker at this virtual time")
    ap.add_argument("--crash-worker", type=int, default=1,
                    help="worker id for the crash/rejoin scenario")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the run's "
                         "per-request spans (deterministic: bit-identical "
                         "across replays of the same seed)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a metrics snapshot at end of run "
                         "(.prom/.txt -> Prometheus text exposition, "
                         "else canonical JSON)")
    ap.add_argument("--trace-profile", action="store_true",
                    help="profile kernel dispatches (wall clock) and "
                         "include the wall-clock spans/metrics in the "
                         "artifacts — the outputs are then NOT "
                         "replay-stable")
    ap.add_argument("--scrape-every", type=float, default=None,
                    metavar="VIRT_S",
                    help="streaming obs: flush completed trace spans and a "
                         "metrics scrape to rotating segments every this "
                         "many virtual seconds (bounds recorder memory)")
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="segment directory for streaming obs (default "
                         "obs_<trace>_seed<seed> when streaming is on)")
    ap.add_argument("--trace-sample", type=float, default=None,
                    metavar="RATE",
                    help="deterministic per-request trace sampling rate in "
                         "[0,1]; anomalous requests (escalations, expiries, "
                         "rescues) are always kept")
    ap.add_argument("--trace-head", type=int, default=8,
                    help="always keep the first N request trees regardless "
                         "of --trace-sample")
    ap.add_argument("--trace-cap", type=int, default=None, metavar="N",
                    help="hard per-worker buffered-event cap: new request "
                         "trees are shed (with drop accounting) past it")
    ap.add_argument("--slo-p95", type=float, default=None, metavar="VIRT_S",
                    help="SLO: p95 e2e latency target (error budget 5%%)")
    ap.add_argument("--slo-miss-rate", type=float, default=None,
                    metavar="FRAC",
                    help="SLO: allowed deadline-miss fraction")
    ap.add_argument("--slo-quality-floor", type=float, default=None,
                    help="SLO: per-request quality floor (error budget 10%%)")
    ap.add_argument("--slo-spend", type=float, default=None, metavar="USD",
                    help="SLO: $ spend allowed per --slo-window")
    ap.add_argument("--slo-window", type=float, default=0.25,
                    metavar="VIRT_S",
                    help="SLO compliance window, virtual seconds (the "
                         "burn-rate alert pairs it with a window/12 short "
                         "window)")
    args = ap.parse_args(argv)
    if (args.crash_at is not None and args.rejoin_at is not None
            and args.rejoin_at <= args.crash_at):
        ap.error(f"--rejoin-at ({args.rejoin_at}) must be after "
                 f"--crash-at ({args.crash_at})")

    names = args.pool.split(",")
    engine, data, te = build_routed_engine(
        names, seed=args.seed, epochs=args.epochs, lam=args.lam,
        use_pallas=args.pallas,
        quality_kind="attn-ens" if args.cascade else "attn",
        restore_router=args.restore_router)
    if args.save_router:
        from repro.checkpoint import save_router

        save_router(args.save_router, engine.router, pool_names=names)
        print(f"router checkpoint saved to {args.save_router} "
              f"(v{engine.router.version}, "
              f"{engine.router.quality_kind}/{engine.router.cost_kind})")

    trace = make_trace(
        TraceConfig(
            kind=args.trace, n_requests=args.requests, rate=args.rate,
            seed=args.seed, max_new=args.max_new, deadline_s=args.deadline,
            prompt_len_max=48,
            vocab=min(m.cfg.vocab_size for m in engine.pool),
        ),
        texts=[data.texts[i] for i in te],
        benchmarks=[data.benchmark[i] for i in te],
    )

    # Quality truth lookup (--online feedback and --cascade per-leg
    # observed quality), built once and shared by every consumer.
    qual_of_text = None
    if args.online or args.cascade:
        quality = data.quality[:, pool_quality_columns(engine.pool, data)]
        qual_of_text = {data.texts[i]: quality[i]
                        for i in range(len(data.texts))}

    def truth(req):
        return float(qual_of_text[req.text][req.member])

    def make_cascade(governor):
        """Fresh cascade coordinator bound to one scheduler's governor."""
        if not args.cascade:
            return None
        from repro.cascade import (
            CascadeConfig, CascadeCoordinator, CascadePolicy, cost_ladder,
        )

        policy = CascadePolicy(
            cost_ladder(engine.router),
            CascadeConfig(max_legs=args.max_legs, beta=args.cascade_beta,
                          margin=args.cascade_margin,
                          min_headroom=args.cascade_min_headroom),
            reward=engine.router.reward)
        # Observed leg quality: the synthetic RouterBench truth stands in
        # for the deployment's response evaluator.
        return CascadeCoordinator(policy, observed_quality=truth,
                                  governor=governor)

    def make_semcache():
        """Fresh rung-0 semantic cache (policy/drift hooks are wired by the
        scheduler from the cascade policy and the adapter's detector)."""
        if not args.semcache:
            return None
        radius = args.cache_radius
        if radius is None:
            tr, _, _ = data.split(seed=args.seed)
            radius = calibrate_radius(data.emb[tr])
            print(f"semcache radius calibrated to {radius:.4f} "
                  f"(training-split NN-distance quantile)")
        return SemanticCache(radius, cap=args.cache_cap)

    def make_feedback(seed):
        """(quality_feedback, feedback_source, stage) for one adapter."""
        if args.feedback_delay > 0:
            from repro.online import DelayedFeedback, OutcomeStage
            fb = DelayedFeedback(truth, args.feedback_delay,
                                 jitter_s=args.feedback_delay * 0.5,
                                 seed=seed)
            # Bound how long unresolved outcomes are held: well past the
            # worst-case delivery delay, but never forever.
            stage = OutcomeStage(timeout_s=20.0 * args.feedback_delay)
            return fb, fb, stage
        return truth, None, None

    obs = _setup_obs(args)
    if args.workers > 1:
        return _run_plane(args, engine, data, trace, make_feedback,
                          make_cascade, obs, make_semcache)
    recorder, registry, profiler, flusher = obs

    governor = None
    if args.budget > 0:
        governor = BudgetGovernor(args.budget, args.budget_window,
                                  lam0=args.lam)

    adapter = None
    if args.online:
        from repro.online import (
            DriftDetector, ExplorationConfig, OnlineAdapter,
            OnlineUpdateConfig,
        )

        # Quality feedback: the synthetic RouterBench truth stands in for
        # user ratings / auto-eval (the held-out split is what the trace
        # samples its texts from).
        quality_feedback, feedback_source, stage = make_feedback(args.seed)
        tr, _, _ = data.split(seed=args.seed)
        drift = DriftDetector(window=48).fit(
            data.emb[tr], engine.router.centroids)
        membership = None
        if args.refresh_established:
            from repro.online import MembershipTracker

            membership = MembershipTracker(
                engine, refresh_established=True)
        adapter = OnlineAdapter(
            engine, quality_feedback, governor=governor,
            config=OnlineUpdateConfig(
                update_every=args.online_update_every),
            exploration=ExplorationConfig(epsilon=args.epsilon,
                                          seed=args.seed),
            drift=drift, feedback_source=feedback_source, stage=stage,
            membership=membership,
            seed=args.seed,
        )

    cascade = make_cascade(governor)
    semcache = make_semcache()
    slo = _make_slo(args, tracer=recorder)
    sched = MicroBatchScheduler(
        engine,
        SchedulerConfig(score_batch=args.score_batch,
                        max_batch=args.max_batch,
                        max_wait_s=args.max_wait,
                        queue_capacity=args.queue_capacity),
        governor=governor,
        service_time=None if args.wall_time else default_service_model(),
        adapter=adapter, cascade=cascade, semcache=semcache,
        tracer=recorder.scoped(0) if recorder is not None else None,
        slo=slo, flusher=flusher,
    )
    if registry is not None:
        from repro.obs import (
            register_governor_metrics, register_scheduler_metrics,
            register_slo_metrics, register_stream_metrics,
        )

        register_scheduler_metrics(registry, sched)
        if governor is not None:
            register_governor_metrics(registry, governor,
                                      lambda: sched.clock.now)
        if slo is not None:
            register_slo_metrics(registry, slo, lambda: sched.clock.now)
        if flusher is not None:
            register_stream_metrics(registry, flusher)
    summary = sched.run_trace(trace)

    print(f"trace={args.trace} requests={args.requests} seed={args.seed}")
    print(sched.telemetry.report(summary.get("duration_s")))
    if cascade is not None:
        print(cascade.report())
    if semcache is not None:
        rep = semcache.report()
        print(f"semcache: {rep['served']} served / {rep['lookups']} lookups "
              f"(hit rate {rep['hit_rate']:.2f})  "
              f"{rep['fallthroughs']} fallthroughs  "
              f"{rep['stale_hits']} stale  {rep['evicted']} evicted  "
              f"{rep['invalidations']} invalidated  "
              f"{rep['entries']} entries")
    if adapter is not None:
        print(adapter.report())
    if governor is not None:
        g = governor.summary(sched.clock.now)
        print(f"budget ${g['budget_per_window']:.4f}/{args.budget_window}s "
              f"window  spend ${g['total_spend']:.6f}  "
              f"final lambda {g['lam']:.3g} (nominal {g['lam0']:.3g})  "
              f"tightened x{int(g['tightened'])} relaxed x{int(g['relaxed'])}")
    _print_slo(slo, sched.clock.now)
    _save_obs(args, recorder, registry, profiler, flusher,
              now=sched.clock.now)
    return summary


def _run_plane(args, engine, data, trace, make_feedback, make_cascade,
               obs=(None, None, None, None), make_semcache=lambda: None):
    """Multi-worker path: build N workers + coordinator, run the plane."""
    from repro.distributed import (
        Coordinator, PlaneEvent, ServingPlane, SharedBudgetLedger,
        SyncConfig, WorkerNode,
    )
    from repro.serving.scheduler import SimClock

    recorder, registry, profiler, flusher = obs
    # One fleet-level SLO tracker: every worker's finalized requests feed
    # the same rolling windows (they tolerate cross-worker time skew).
    slo = _make_slo(args, tracer=recorder)
    governor = None
    if args.budget > 0:
        governor = SharedBudgetLedger(args.budget, args.budget_window,
                                      lam0=args.lam)

    drift_proto = None
    if args.online:
        from repro.online import DriftDetector

        tr, _, _ = data.split(seed=args.seed)
        # Per-worker detectors over each worker's 1/N traffic share:
        # smaller windows, alarms escalate to a leader burst. The bootstrap
        # calibration is identical for every worker, so fit ONCE and clone
        # the fitted detector instead of paying N calibration passes.
        drift_proto = DriftDetector(window=max(16, 48 // args.workers)).fit(
            data.emb[tr], engine.router.centroids)

    workers = []
    for wid in range(args.workers):
        weng = RoutedEngine(router=engine.router, pool=engine.pool,
                            lam=args.lam, use_pallas=args.pallas)
        adapter = None
        if args.online:
            import copy

            from repro.online import (
                ExplorationConfig, OnlineAdapter, OnlineUpdateConfig,
            )

            wseed = args.seed + 101 * wid + 1
            quality_feedback, feedback_source, stage = make_feedback(wseed)
            membership = None
            if args.refresh_established:
                from repro.online import MembershipTracker

                membership = MembershipTracker(
                    weng, refresh_established=True)
            adapter = OnlineAdapter(
                weng, quality_feedback, governor=governor,
                config=OnlineUpdateConfig(
                    update_every=args.online_update_every),
                exploration=ExplorationConfig(epsilon=args.epsilon,
                                              seed=wseed),
                drift=copy.deepcopy(drift_proto),
                feedback_source=feedback_source, stage=stage,
                membership=membership,
                defer_updates=True, seed=wseed,
            )
        sched = MicroBatchScheduler(
            weng,
            SchedulerConfig(score_batch=args.score_batch,
                            max_batch=args.max_batch,
                            max_wait_s=args.max_wait,
                            queue_capacity=args.queue_capacity),
            governor=governor, clock=SimClock(),
            service_time=None if args.wall_time else default_service_model(),
            adapter=adapter, cascade=make_cascade(governor),
            semcache=make_semcache(),
            tracer=recorder.scoped(wid) if recorder is not None else None,
            slo=slo,
        )
        workers.append(WorkerNode(wid, weng, sched, adapter))

    from repro.online import OnlineUpdateConfig
    coord = Coordinator(workers, SyncConfig(
        sync_every_s=args.sync_every, seed=args.seed,
        update=OnlineUpdateConfig(update_every=args.online_update_every)))
    events = []
    if args.crash_at is not None:
        events.append(PlaneEvent(args.crash_at, "crash", args.crash_worker))
        if args.rejoin_at is not None:
            events.append(
                PlaneEvent(args.rejoin_at, "rejoin", args.crash_worker))
    plane = ServingPlane(workers, coord, events=events, tracer=recorder,
                         flusher=flusher)
    if registry is not None:
        from repro.obs import (
            register_plane_metrics, register_slo_metrics,
            register_stream_metrics,
        )

        register_plane_metrics(registry, plane)
        if slo is not None:
            register_slo_metrics(
                registry, slo,
                lambda: max(w.clock.now for w in plane.workers.values()))
        if flusher is not None:
            register_stream_metrics(registry, flusher)
    summary = plane.run_trace(trace)

    print(f"trace={args.trace} requests={args.requests} seed={args.seed} "
          f"workers={args.workers}")
    print(plane.report(summary.get("duration_s")))
    if args.cascade:
        for w in sorted(workers, key=lambda w: w.wid):
            print(f"w{w.wid} {w.scheduler.cascade.report()}")
    if args.semcache:
        for w in sorted(workers, key=lambda w: w.wid):
            rep = w.scheduler.semcache.report()
            print(f"w{w.wid} semcache: {rep['served']}/{rep['lookups']} "
                  f"served (hit rate {rep['hit_rate']:.2f})  "
                  f"{rep['entries']} entries")
    if args.online:
        for w in sorted(workers, key=lambda w: w.wid):
            print(f"w{w.wid} {w.adapter.report()}")
    if governor is not None:
        now = max(w.clock.now for w in workers)
        g = governor.summary(now)
        print(f"shared budget ${g['budget_per_window']:.4f}/"
              f"{args.budget_window}s window  spend ${g['total_spend']:.6f}  "
              f"final lambda {g['lam']:.3g} (nominal {g['lam0']:.3g})  "
              f"tightened x{int(g['tightened'])} relaxed x{int(g['relaxed'])} "
              f"throttled x{governor.throttled}")
    t_end = max(w.clock.now for w in workers)
    _print_slo(slo, t_end)
    _save_obs(args, recorder, registry, profiler, flusher, now=t_end)
    return summary


if __name__ == "__main__":
    main()
