"""Streaming routed-serving driver: simulated open-loop traffic end to end.

    PYTHONPATH=src python -m repro.launch.serve --trace poisson --requests 200
    PYTHONPATH=src python -m repro.launch.serve --trace bursty --requests 200 \
        --budget 0.02 --budget-window 0.5 --lam 1.0
    PYTHONPATH=src python -m repro.launch.serve --trace drift --requests 400 \
        --workers 4 --online --crash-at 0.1 --rejoin-at 0.3
    PYTHONPATH=src python -m repro.launch.serve --trace poisson --requests 200 \
        --cascade --max-legs 3 --budget 0.02
    PYTHONPATH=src python -m repro.launch.serve --trace drift --requests 200 \
        --workers 2 --online --transport socket

``--cascade`` trains the deep-ensemble quality head and runs multi-leg
escalation (repro.cascade): answers that look inadequate against the next
cost-ladder rung's expected marginal reward are re-admitted at elevated
priority, every leg is charged to the budget ledger, and telemetry splits
quality/cost/latency by leg. ``--semcache`` adds a semantic answer cache
as rung 0 of that ladder: near-duplicate queries (see ``--trace neardup``)
are answered from cache when the rung-0 stop-vs-escalate decision — the
same expected-marginal-reward math as the cascade — says the cached
answer's risk-adjusted quality beats paying for generation. ``--save-router`` / ``--restore-router``
persist the trained router (params + version + cost-scaler meta); restored
routers score bitwise-identically.

Builds reduced pool members on CPU (full configs require the production
mesh), trains the attention router on synthetic RouterBench traffic mapped
onto the pool, then replays a simulated traffic scenario (poisson / bursty /
drift) through the admission queue + continuous micro-batching scheduler,
reporting per-member counts, spend vs. budget, and latency percentiles.

``--workers N`` (N > 1) runs the multi-worker serving plane instead of the
single scheduler: N workers (each with its own engine replica, queue, and
virtual clock) share the pool and — with ``--budget`` — one global
SharedBudgetLedger; with ``--online`` the workers run follower adapters
and the coordinator periodically merges their replay buffers onto the
leader, runs the bounded update steps there, and broadcasts the versioned
router to every worker. ``--crash-at``/``--rejoin-at`` inject a worker
crash-and-rejoin scenario; ``--feedback-delay`` routes quality feedback
through the staged delayed-outcome path.

``--transport`` picks how the plane's message protocol is carried:
``local`` (default) delivers by reference in-process and replays
bit-identically; ``socket`` launches workers 1..N-1 as real OS processes
(``repro.distributed.host``) speaking length-prefixed TCP to this
controller process (worker 0, which is also the lowest-id leader), with
the LM pool sharded by ownership across the processes — each generate
leg runs on the member's owning worker. ``--metrics-port`` serves the
live metrics registry over localhost HTTP (``/metrics`` Prometheus text,
``/metrics.json`` canonical JSON) for the run's duration.

Every random path — pool init, synthetic traffic, router training, the
trace arrival/content sampling, and the prompt token RNG — derives from
``--seed``, so runs are reproducible end to end; socket-mode follower
processes rebuild identical engine/corpus/truth state by re-parsing the
controller's forwarded argv.
"""
from __future__ import annotations

import argparse
import os
import sys
import types

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import build_model_embeddings
from repro.core.router import PredictiveRouter
from repro.data import generate
from repro.models import lm as lm_mod
from repro.serving import (
    TRACE_KINDS,
    BudgetGovernor,
    MicroBatchScheduler,
    PoolMember,
    RoutedEngine,
    SchedulerConfig,
    SemanticCache,
    TraceConfig,
    arch_cost_rate,
    calibrate_radius,
    default_service_model,
    make_trace,
)
from repro.training import train_dual_predictors


def build_pool(names, seed: int = 0):
    """Reduced configs execute on CPU; cost rates come from the FULL
    configs (the economics the router must learn are those of the real
    architectures, not of the smoke-test stand-ins)."""
    from repro.configs import get_config

    members = []
    for i, name in enumerate(names):
        cfg = get_smoke_config(name)
        params = lm_mod.init_lm(jax.random.key(seed + i), cfg)
        members.append(PoolMember(
            name=name, cfg=cfg, params=params,
            quality_profile=None,
            cost_rate=arch_cost_rate(get_config(name)),
        ))
    return members


def pool_quality_columns(pool, data) -> list:
    """RouterBench quality columns for the pool members, matched by cost
    order (cheapest member <- cheapest API model, etc.)."""
    api_cost_order = np.argsort(data.cost.mean(0))          # cheap -> pricey
    member_rank = np.argsort(np.argsort([m.cost_rate for m in pool]))
    k_api, p = len(api_cost_order), len(pool)
    return [
        int(api_cost_order[int(round(member_rank[i] * (k_api - 1) / max(p - 1, 1)))])
        for i in range(p)
    ]


def synthetic_pool_traffic(pool, n: int = 1200, seed: int = 0):
    """Map synthetic RouterBench quality columns onto the pool members."""
    data = generate(n, seed=seed)
    quality = data.quality[:, pool_quality_columns(pool, data)]  # pool order
    cost = np.stack([np.full(n, m.cost_rate) for m in pool], axis=1)
    return data, quality, cost


def build_routed_engine(names, *, seed: int = 0, epochs: int = 120,
                        lam: float = 1.0, n_traffic: int = 1200,
                        use_pallas: bool = False, quality_kind: str = "attn",
                        restore_router: str = None):
    """Pool + trained router + engine, all seeded. Returns (engine, data, te).

    ``quality_kind="attn-ens"`` trains the deep-ensemble quality head (the
    cascade path's uncertainty source). ``restore_router`` skips offline
    predictor training entirely and loads a checkpoint saved by
    ``--save-router`` instead (the pool and traffic corpus are still built
    — they are the serving substrate, not router state).
    """
    pool = build_pool(names, seed=seed)
    data, quality, cost = synthetic_pool_traffic(pool, n=n_traffic, seed=seed)
    tr, va, te = data.split(seed=seed)
    if restore_router is not None:
        from repro.checkpoint import load_router

        router = load_router(restore_router, expect_pool_names=names)
        if router.n_members != len(pool):
            raise ValueError(
                f"checkpoint pool size {router.n_members} != "
                f"serving pool size {len(pool)}")
    else:
        memb, centers = build_model_embeddings(data.emb[tr], quality[tr],
                                               seed=seed)
        qp, cp, scaler, _ = train_dual_predictors(
            quality_kind, "attn", data.emb[tr], quality[tr], cost[tr], memb,
            q_emb_val=data.emb[va], quality_val=quality[va],
            cost_val=cost[va], epochs=epochs, seed=seed,
        )
        # Centroids ride on the router so online hot-added members can be
        # embedded per-cluster from live outcomes (repro.online.membership).
        router = PredictiveRouter(quality_kind, "attn", qp, cp, memb,
                                  reward="R2", cost_scaler=scaler,
                                  centroids=centers)
    engine = RoutedEngine(router=router, pool=pool, lam=lam,
                          use_pallas=use_pallas)
    return engine, data, te


def build_context(args):
    """Everything a serving process derives deterministically from argv.

    The controller and every socket-mode follower call this with the SAME
    parsed argv: the pool init, predictor training, corpus split, truth
    lookup, and the per-scheduler component factories are all seeded by
    ``--seed``, so each process reconstructs bitwise-identical router and
    pool state without shipping parameters over the wire.
    """
    names = args.pool.split(",")
    engine, data, te = build_routed_engine(
        names, seed=args.seed, epochs=args.epochs, lam=args.lam,
        use_pallas=args.pallas,
        quality_kind="attn-ens" if args.cascade else "attn",
        restore_router=args.restore_router)

    # Quality truth lookup (--online feedback and --cascade per-leg
    # observed quality), built once and shared by every consumer.
    qual_of_text = None
    if args.online or args.cascade:
        quality = data.quality[:, pool_quality_columns(engine.pool, data)]
        qual_of_text = {data.texts[i]: quality[i]
                        for i in range(len(data.texts))}

    def truth(req):
        return float(qual_of_text[req.text][req.member])

    def make_cascade(governor):
        """Fresh cascade coordinator bound to one scheduler's governor."""
        if not args.cascade:
            return None
        from repro.cascade import (
            CascadeConfig, CascadeCoordinator, CascadePolicy, cost_ladder,
        )

        policy = CascadePolicy(
            cost_ladder(engine.router),
            CascadeConfig(max_legs=args.max_legs, beta=args.cascade_beta,
                          margin=args.cascade_margin,
                          min_headroom=args.cascade_min_headroom),
            reward=engine.router.reward)
        # Observed leg quality: the synthetic RouterBench truth stands in
        # for the deployment's response evaluator.
        return CascadeCoordinator(policy, observed_quality=truth,
                                  governor=governor)

    def make_semcache():
        """Fresh rung-0 semantic cache (policy/drift hooks are wired by the
        scheduler from the cascade policy and the adapter's detector)."""
        if not args.semcache:
            return None
        radius = args.cache_radius
        if radius is None:
            tr, _, _ = data.split(seed=args.seed)
            radius = calibrate_radius(data.emb[tr])
            print(f"semcache radius calibrated to {radius:.4f} "
                  f"(training-split NN-distance quantile)")
        return SemanticCache(radius, cap=args.cache_cap)

    def make_feedback(seed):
        """(quality_feedback, feedback_source, stage) for one adapter."""
        if args.feedback_delay > 0:
            from repro.online import DelayedFeedback, OutcomeStage
            fb = DelayedFeedback(truth, args.feedback_delay,
                                 jitter_s=args.feedback_delay * 0.5,
                                 seed=seed)
            # Bound how long unresolved outcomes are held: well past the
            # worst-case delivery delay, but never forever.
            stage = OutcomeStage(timeout_s=20.0 * args.feedback_delay)
            return fb, fb, stage
        return truth, None, None

    return types.SimpleNamespace(
        names=names, engine=engine, data=data, te=te, truth=truth,
        make_cascade=make_cascade, make_semcache=make_semcache,
        make_feedback=make_feedback)


def build_drift_proto(args, ctx):
    """Fitted per-worker drift-detector prototype (None without --online).

    Per-worker detectors watch each worker's 1/N traffic share: smaller
    windows, alarms escalate to a leader burst. The bootstrap calibration
    is identical for every worker, so fit ONCE and deep-copy the fitted
    detector instead of paying N calibration passes (socket-mode followers
    refit from the same seeded inputs and land on the same state).
    """
    if not args.online:
        return None
    from repro.online import DriftDetector

    tr, _, _ = ctx.data.split(seed=args.seed)
    return DriftDetector(window=max(16, 48 // args.workers)).fit(
        ctx.data.emb[tr], ctx.engine.router.centroids)


def build_plane_worker(args, ctx, wid, governor, drift_proto, recorder, slo):
    """One plane worker node, identical whichever process builds it.

    ``governor`` is the shared ledger in-process, or a
    :class:`~repro.distributed.ledger.LedgerClient` in a socket-mode
    follower; ``recorder`` is the shared TraceRecorder in-process, or the
    follower's own per-process recorder.
    """
    from repro.distributed import WorkerNode
    from repro.serving.scheduler import SimClock

    weng = RoutedEngine(router=ctx.engine.router, pool=ctx.engine.pool,
                        lam=args.lam, use_pallas=args.pallas)
    adapter = None
    if args.online:
        import copy

        from repro.online import (
            ExplorationConfig, OnlineAdapter, OnlineUpdateConfig,
        )

        wseed = args.seed + 101 * wid + 1
        quality_feedback, feedback_source, stage = ctx.make_feedback(wseed)
        membership = None
        if args.refresh_established:
            from repro.online import MembershipTracker

            membership = MembershipTracker(
                weng, refresh_established=True)
        adapter = OnlineAdapter(
            weng, quality_feedback, governor=governor,
            config=OnlineUpdateConfig(
                update_every=args.online_update_every),
            exploration=ExplorationConfig(epsilon=args.epsilon,
                                          seed=wseed),
            drift=copy.deepcopy(drift_proto),
            feedback_source=feedback_source, stage=stage,
            membership=membership,
            defer_updates=True, seed=wseed,
        )
    sched = MicroBatchScheduler(
        weng,
        SchedulerConfig(score_batch=args.score_batch,
                        max_batch=args.max_batch,
                        max_wait_s=args.max_wait,
                        queue_capacity=args.queue_capacity),
        governor=governor, clock=SimClock(),
        service_time=None if args.wall_time else default_service_model(),
        adapter=adapter, cascade=ctx.make_cascade(governor),
        semcache=ctx.make_semcache(),
        tracer=recorder.scoped(wid) if recorder is not None else None,
        slo=slo,
    )
    sched.slo_enforce = args.slo_class > 0
    return WorkerNode(wid, weng, sched, adapter)


def _streaming_requested(args) -> bool:
    return (args.scrape_every is not None or args.trace_sample is not None
            or args.trace_cap is not None or args.obs_dir is not None)


def _setup_obs(args):
    """(recorder, registry, profiler, flusher) from the obs flags.

    All default to None — the runtime's tracer branches then cost nothing.
    Streaming mode (any of ``--scrape-every/--trace-sample/--trace-cap/
    --obs-dir``) builds the recorder with the sampler/cap installed and an
    :class:`ObsFlusher` over the segment directory; with no
    ``--scrape-every`` the flusher still applies sampling, in one
    final-only flush. ``--trace-profile`` additionally installs the
    kernel-dispatch profiler globally (removed again by :func:`_save_obs`).
    ``--metrics-port`` forces the registry on so the HTTP endpoint has
    something to scrape.
    """
    recorder = registry = profiler = flusher = None
    streaming = _streaming_requested(args)
    label = f"serve-{args.trace}-seed{args.seed}"
    if args.trace_out or args.trace_profile or streaming:
        from repro.obs import TraceRecorder, TraceSampler

        sampler = None
        if args.trace_sample is not None:
            sampler = TraceSampler(args.trace_sample, seed=args.seed,
                                   head=args.trace_head)
        recorder = TraceRecorder(
            label=label, sampler=sampler,
            max_buffered_per_worker=args.trace_cap)
    if args.metrics_out or args.metrics_port is not None or streaming:
        from repro.obs import MetricsRegistry

        registry = MetricsRegistry()
    if streaming:
        from repro.obs import ObsFlusher

        obs_dir = args.obs_dir or f"obs_{args.trace}_seed{args.seed}"
        args.obs_dir = obs_dir
        flusher = ObsFlusher(
            obs_dir, recorder=recorder, registry=registry,
            scrape_every_s=args.scrape_every, label=label,
            include_wall=args.trace_profile,
            deterministic_metrics=not args.trace_profile)
    if args.trace_profile:
        from repro.kernels import ops as kops
        from repro.obs import KernelProfiler

        profiler = KernelProfiler(tracer=recorder)
        kops.set_kernel_profiler(profiler)
    return recorder, registry, profiler, flusher


def _save_obs(args, recorder, registry, profiler, flusher=None,
              now: float = 0.0):
    """Write the observability artifacts and uninstall the profiler.

    ``now`` is the run's final virtual time — it stamps the flusher's
    last segment and manifest. In streaming mode ``--trace-out`` becomes
    the concatenation of the rotated segments (still one valid,
    replay-stable Chrome trace — minus sampled-out request trees).
    """
    if profiler is not None:
        from repro.kernels import ops as kops

        kops.set_kernel_profiler(None)
        print(profiler.report())
        if registry is not None:
            profiler.register_metrics(registry)
    if flusher is not None:
        flusher.finalize(now)
        stats = recorder.drop_stats
        print(f"obs segments written to {args.obs_dir} "
              f"({flusher.seq} flushes, peak {recorder.peak_buffered} "
              f"buffered events, {stats['requests_sampled_out']} trees "
              f"sampled out, {stats['requests_shed']} shed)")
        if args.trace_out:
            import json as _json

            from repro.obs import concat_dir

            doc = concat_dir(args.obs_dir)
            with open(args.trace_out, "w") as f:
                f.write(_json.dumps(doc, sort_keys=True,
                                    separators=(",", ":")))
            print(f"concatenated trace written to {args.trace_out}")
    elif recorder is not None and args.trace_out:
        recorder.save(args.trace_out, include_wall=args.trace_profile)
        print(f"trace written to {args.trace_out} "
              f"({recorder.n_events} events)")
    if registry is not None and args.metrics_out:
        if args.metrics_out.endswith((".prom", ".txt")):
            registry.save_prometheus(args.metrics_out)
        else:
            # Deterministic snapshot unless the operator opted wall-clock
            # data in — replays of a seeded run then produce identical
            # bytes, same contract as the trace.
            registry.save(args.metrics_out,
                          deterministic=not args.trace_profile)
        print(f"metrics snapshot written to {args.metrics_out} "
              f"({len(registry)} series)")


def _make_slo(args, tracer=None):
    """SLO tracker from the --slo-* flags (None when none are set)."""
    from repro.obs import build_slo_tracker

    return build_slo_tracker(
        tracer=tracer, p95_target_s=args.slo_p95,
        miss_rate_budget=args.slo_miss_rate,
        quality_floor=args.slo_quality_floor,
        spend_per_window=args.slo_spend, window_s=args.slo_window)


def _print_slo(slo, now: float) -> None:
    if slo is None:
        return
    firing = slo.firing()
    burns = {name: f"{b['long']:.2f}x"
             for name, b in slo.burn_rates(now).items()}
    print(f"slo: {slo.alerts_total} alert transitions  "
          f"firing {firing if firing else 'none'}  long-window burn "
          + "  ".join(f"{k}={v}" for k, v in burns.items()))


def make_parser() -> argparse.ArgumentParser:
    """The serve argv schema — shared with ``repro.distributed.host``,
    which re-parses the controller's forwarded argv to rebuild identical
    serving state in each follower process."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pool", default="qwen3-0.6b,granite-moe-1b-a400m,granite-3-8b")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--trace", default="poisson", choices=TRACE_KINDS)
    ap.add_argument("--rate", type=float, default=400.0,
                    help="mean arrivals per virtual second")
    ap.add_argument("--lam", type=float, default=1.0,
                    help="nominal willingness-to-pay")
    ap.add_argument("--budget", type=float, default=0.0,
                    help="$ budget per rolling window (0 disables governor)")
    ap.add_argument("--budget-window", type=float, default=0.5,
                    help="governor window, virtual seconds")
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds pool init, traffic, training, trace and prompts")
    ap.add_argument("--epochs", type=int, default=80)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait", type=float, default=0.05)
    ap.add_argument("--score-batch", type=int, default=64)
    ap.add_argument("--queue-capacity", type=int, default=512)
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline, virtual seconds after arrival")
    ap.add_argument("--pallas", action="store_true",
                    help="score through the fused Pallas router_xattn path")
    ap.add_argument("--wall-time", action="store_true",
                    help="advance the virtual clock by measured wall time "
                         "instead of the deterministic service model")
    ap.add_argument("--online", action="store_true",
                    help="online adaptation: replay-buffered outcome "
                         "feedback, drift detection, exploration, and "
                         "incremental router updates during serving")
    ap.add_argument("--cascade", action="store_true",
                    help="cascade routing: train the deep-ensemble quality "
                         "head and escalate inadequate answers up the cost "
                         "ladder (multi-leg requests, cumulative-cost "
                         "budget accounting)")
    ap.add_argument("--max-legs", type=int, default=3,
                    help="cascade: max legs per request")
    ap.add_argument("--cascade-beta", type=float, default=1.0,
                    help="cascade: optimism width on untried rungs "
                         "(x ensemble std)")
    ap.add_argument("--cascade-margin", type=float, default=0.0,
                    help="cascade: required expected marginal reward to "
                         "escalate")
    ap.add_argument("--cascade-min-headroom", type=float, default=0.0,
                    help="cascade: budget headroom in [0,1] below which "
                         "escalation is blocked (0 disables the gate; "
                         "needs --budget to have any effect)")
    ap.add_argument("--semcache", action="store_true",
                    help="semantic answer cache as cascade rung 0: "
                         "embedding-keyed reuse of finalized answers for "
                         "near-duplicate queries, stop-vs-escalate decided "
                         "by the same expected-marginal-reward policy as "
                         "the cascade ladder")
    ap.add_argument("--cache-radius", type=float, default=None,
                    help="semcache: L2 match radius in embedding space "
                         "(default: calibrated from the training split's "
                         "nearest-neighbour distance quantile)")
    ap.add_argument("--cache-cap", type=int, default=256,
                    help="semcache: max entries (LRU eviction past it)")
    ap.add_argument("--save-router", default=None, metavar="PATH",
                    help="persist the trained router (params + version + "
                         "cost-scaler meta) after offline training")
    ap.add_argument("--restore-router", default=None, metavar="PATH",
                    help="load a --save-router checkpoint instead of "
                         "training (restored scores are bitwise-identical)")
    ap.add_argument("--refresh-established", action="store_true",
                    help="online: EMA outcome-driven embedding refresh for "
                         "graduated (established) pool members under drift")
    ap.add_argument("--online-update-every", type=int, default=32,
                    help="outcomes between scheduled incremental updates")
    ap.add_argument("--epsilon", type=float, default=0.05,
                    help="exploration rate at full budget headroom")
    ap.add_argument("--feedback-delay", type=float, default=0.0,
                    help="virtual seconds between completion and quality "
                         "feedback (staged delayed-outcome path; 0 = "
                         "feedback at completion time)")
    ap.add_argument("--workers", type=int, default=1,
                    help="N>1 runs the multi-worker serving plane "
                         "(repro.distributed) with leader/follower sync")
    ap.add_argument("--transport", default="local",
                    choices=["local", "socket"],
                    help="plane message transport: local = in-process "
                         "by-reference delivery (bit-identical seeded "
                         "replays); socket = workers 1..N-1 as real OS "
                         "processes over length-prefixed TCP, with the LM "
                         "pool sharded by ownership across the processes")
    ap.add_argument("--sync-every", type=float, default=0.05,
                    help="virtual seconds between replay-merge/broadcast "
                         "sync rounds (multi-worker only)")
    ap.add_argument("--crash-at", type=float, default=None,
                    help="crash --crash-worker at this virtual time "
                         "(multi-worker only)")
    ap.add_argument("--rejoin-at", type=float, default=None,
                    help="rejoin the crashed worker at this virtual time")
    ap.add_argument("--crash-worker", type=int, default=1,
                    help="worker id for the crash/rejoin scenario")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the run's "
                         "per-request spans (deterministic: bit-identical "
                         "across replays of the same seed)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a metrics snapshot at end of run "
                         "(.prom/.txt -> Prometheus text exposition, "
                         "else canonical JSON)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve the live metrics registry over localhost "
                         "HTTP for the run's duration (/metrics Prometheus "
                         "text, /metrics.json canonical JSON; 0 picks an "
                         "ephemeral port)")
    ap.add_argument("--trace-profile", action="store_true",
                    help="profile kernel dispatches (wall clock) and "
                         "include the wall-clock spans/metrics in the "
                         "artifacts — the outputs are then NOT "
                         "replay-stable")
    ap.add_argument("--scrape-every", type=float, default=None,
                    metavar="VIRT_S",
                    help="streaming obs: flush completed trace spans and a "
                         "metrics scrape to rotating segments every this "
                         "many virtual seconds (bounds recorder memory)")
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="segment directory for streaming obs (default "
                         "obs_<trace>_seed<seed> when streaming is on)")
    ap.add_argument("--trace-sample", type=float, default=None,
                    metavar="RATE",
                    help="deterministic per-request trace sampling rate in "
                         "[0,1]; anomalous requests (escalations, expiries, "
                         "rescues) are always kept")
    ap.add_argument("--trace-head", type=int, default=8,
                    help="always keep the first N request trees regardless "
                         "of --trace-sample")
    ap.add_argument("--trace-cap", type=int, default=None, metavar="N",
                    help="hard per-worker buffered-event cap: new request "
                         "trees are shed (with drop accounting) past it")
    ap.add_argument("--slo-p95", type=float, default=None, metavar="VIRT_S",
                    help="SLO: p95 e2e latency target (error budget 5%%)")
    ap.add_argument("--slo-miss-rate", type=float, default=None,
                    metavar="FRAC",
                    help="SLO: allowed deadline-miss fraction")
    ap.add_argument("--slo-quality-floor", type=float, default=None,
                    help="SLO: per-request quality floor (error budget 10%%)")
    ap.add_argument("--slo-spend", type=float, default=None, metavar="USD",
                    help="SLO: $ spend allowed per --slo-window")
    ap.add_argument("--slo-window", type=float, default=0.25,
                    metavar="VIRT_S",
                    help="SLO compliance window, virtual seconds (the "
                         "burn-rate alert pairs it with a window/12 short "
                         "window)")
    ap.add_argument("--slo-class", type=int, default=0, metavar="K",
                    help="SLO-class-aware admission enforcement: assign "
                         "each trace request a class in [0, K) (round-"
                         "robin over arrival order; higher = more "
                         "important) and, while any --slo-* burn-rate "
                         "alert fires, shed the queue's lowest class at "
                         "dispatch time (0 disables)")
    return ap


def main(argv=None):
    ap = make_parser()
    args = ap.parse_args(argv)
    # Socket mode forwards the raw argv to follower processes, which
    # re-parse it to rebuild identical seeded state.
    raw_argv = list(sys.argv[1:]) if argv is None else list(argv)
    if (args.crash_at is not None and args.rejoin_at is not None
            and args.rejoin_at <= args.crash_at):
        ap.error(f"--rejoin-at ({args.rejoin_at}) must be after "
                 f"--crash-at ({args.crash_at})")
    if args.transport == "socket":
        if args.workers < 2:
            ap.error("--transport socket needs --workers >= 2")
        if args.crash_at is not None and args.crash_worker == 0:
            ap.error("--transport socket pins the controller (and leader) "
                     "to worker 0; crash a follower instead")

    ctx = build_context(args)
    if args.save_router:
        from repro.checkpoint import save_router

        save_router(args.save_router, ctx.engine.router,
                    pool_names=ctx.names)
        print(f"router checkpoint saved to {args.save_router} "
              f"(v{ctx.engine.router.version}, "
              f"{ctx.engine.router.quality_kind}/"
              f"{ctx.engine.router.cost_kind})")

    trace = make_trace(
        TraceConfig(
            kind=args.trace, n_requests=args.requests, rate=args.rate,
            seed=args.seed, max_new=args.max_new, deadline_s=args.deadline,
            prompt_len_max=48,
            vocab=min(m.cfg.vocab_size for m in ctx.engine.pool),
        ),
        texts=[ctx.data.texts[i] for i in ctx.te],
        benchmarks=[ctx.data.benchmark[i] for i in ctx.te],
    )
    if args.slo_class > 0:
        # Deterministic class assignment (arrival order) — followers see
        # the classes via the ASSIGN codec, not by re-deriving them.
        for i, r in enumerate(trace):
            r.slo_class = i % args.slo_class

    obs = _setup_obs(args)
    mserver = None
    if args.metrics_port is not None:
        from repro.obs import MetricsServer

        mserver = MetricsServer(obs[1], port=args.metrics_port)
        print(f"metrics endpoint: http://127.0.0.1:{mserver.start()}"
              f"/metrics")
    try:
        if args.workers > 1:
            if args.transport == "socket":
                return _run_plane_socket(args, ctx, trace, obs, raw_argv,
                                         mserver=mserver)
            return _run_plane(args, ctx, trace, obs)
        return _run_solo(args, ctx, trace, obs)
    finally:
        if mserver is not None:
            mserver.stop()


def _run_solo(args, ctx, trace, obs):
    """Single-scheduler path (``--workers 1``)."""
    recorder, registry, profiler, flusher = obs
    engine, data = ctx.engine, ctx.data

    governor = None
    if args.budget > 0:
        governor = BudgetGovernor(args.budget, args.budget_window,
                                  lam0=args.lam)

    adapter = None
    if args.online:
        from repro.online import (
            DriftDetector, ExplorationConfig, OnlineAdapter,
            OnlineUpdateConfig,
        )

        # Quality feedback: the synthetic RouterBench truth stands in for
        # user ratings / auto-eval (the held-out split is what the trace
        # samples its texts from).
        quality_feedback, feedback_source, stage = ctx.make_feedback(args.seed)
        tr, _, _ = data.split(seed=args.seed)
        drift = DriftDetector(window=48).fit(
            data.emb[tr], engine.router.centroids)
        membership = None
        if args.refresh_established:
            from repro.online import MembershipTracker

            membership = MembershipTracker(
                engine, refresh_established=True)
        adapter = OnlineAdapter(
            engine, quality_feedback, governor=governor,
            config=OnlineUpdateConfig(
                update_every=args.online_update_every),
            exploration=ExplorationConfig(epsilon=args.epsilon,
                                          seed=args.seed),
            drift=drift, feedback_source=feedback_source, stage=stage,
            membership=membership,
            seed=args.seed,
        )

    cascade = ctx.make_cascade(governor)
    semcache = ctx.make_semcache()
    slo = _make_slo(args, tracer=recorder)
    sched = MicroBatchScheduler(
        engine,
        SchedulerConfig(score_batch=args.score_batch,
                        max_batch=args.max_batch,
                        max_wait_s=args.max_wait,
                        queue_capacity=args.queue_capacity),
        governor=governor,
        service_time=None if args.wall_time else default_service_model(),
        adapter=adapter, cascade=cascade, semcache=semcache,
        tracer=recorder.scoped(0) if recorder is not None else None,
        slo=slo, flusher=flusher,
    )
    sched.slo_enforce = args.slo_class > 0
    if registry is not None:
        from repro.obs import (
            register_governor_metrics, register_scheduler_metrics,
            register_slo_metrics, register_stream_metrics,
        )

        register_scheduler_metrics(registry, sched)
        if governor is not None:
            register_governor_metrics(registry, governor,
                                      lambda: sched.clock.now)
        if slo is not None:
            register_slo_metrics(registry, slo, lambda: sched.clock.now)
        if flusher is not None:
            register_stream_metrics(registry, flusher)
    summary = sched.run_trace(trace)

    print(f"trace={args.trace} requests={args.requests} seed={args.seed}")
    print(sched.telemetry.report(summary.get("duration_s")))
    if cascade is not None:
        print(cascade.report())
    if semcache is not None:
        rep = semcache.report()
        print(f"semcache: {rep['served']} served / {rep['lookups']} lookups "
              f"(hit rate {rep['hit_rate']:.2f})  "
              f"{rep['fallthroughs']} fallthroughs  "
              f"{rep['stale_hits']} stale  {rep['evicted']} evicted  "
              f"{rep['invalidations']} invalidated  "
              f"{rep['entries']} entries")
    if adapter is not None:
        print(adapter.report())
    if governor is not None:
        g = governor.summary(sched.clock.now)
        print(f"budget ${g['budget_per_window']:.4f}/{args.budget_window}s "
              f"window  spend ${g['total_spend']:.6f}  "
              f"final lambda {g['lam']:.3g} (nominal {g['lam0']:.3g})  "
              f"tightened x{int(g['tightened'])} relaxed x{int(g['relaxed'])}")
    _print_slo(slo, sched.clock.now)
    _save_obs(args, recorder, registry, profiler, flusher,
              now=sched.clock.now)
    return summary


def _run_plane(args, ctx, trace, obs):
    """Multi-worker path over LocalTransport: N in-process workers."""
    from repro.distributed import (
        Coordinator, PlaneEvent, ServingPlane, SharedBudgetLedger,
        SyncConfig,
    )

    recorder, registry, profiler, flusher = obs
    # One fleet-level SLO tracker: every worker's finalized requests feed
    # the same rolling windows (they tolerate cross-worker time skew).
    slo = _make_slo(args, tracer=recorder)
    governor = None
    if args.budget > 0:
        governor = SharedBudgetLedger(args.budget, args.budget_window,
                                      lam0=args.lam)

    drift_proto = build_drift_proto(args, ctx)
    workers = [
        build_plane_worker(args, ctx, wid, governor, drift_proto,
                           recorder, slo)
        for wid in range(args.workers)
    ]

    from repro.online import OnlineUpdateConfig
    coord = Coordinator(workers, SyncConfig(
        sync_every_s=args.sync_every, seed=args.seed,
        update=OnlineUpdateConfig(update_every=args.online_update_every)))
    events = []
    if args.crash_at is not None:
        events.append(PlaneEvent(args.crash_at, "crash", args.crash_worker))
        if args.rejoin_at is not None:
            events.append(
                PlaneEvent(args.rejoin_at, "rejoin", args.crash_worker))
    plane = ServingPlane(workers, coord, events=events, tracer=recorder,
                         flusher=flusher)
    if registry is not None:
        from repro.obs import (
            register_plane_metrics, register_slo_metrics,
            register_stream_metrics,
        )

        register_plane_metrics(registry, plane)
        if slo is not None:
            register_slo_metrics(
                registry, slo,
                lambda: max(w.clock.now for w in plane.workers.values()))
        if flusher is not None:
            register_stream_metrics(registry, flusher)
    summary = plane.run_trace(trace)

    print(f"trace={args.trace} requests={args.requests} seed={args.seed} "
          f"workers={args.workers}")
    print(plane.report(summary.get("duration_s")))
    if args.cascade:
        for w in sorted(workers, key=lambda w: w.wid):
            print(f"w{w.wid} {w.scheduler.cascade.report()}")
    if args.semcache:
        for w in sorted(workers, key=lambda w: w.wid):
            rep = w.scheduler.semcache.report()
            print(f"w{w.wid} semcache: {rep['served']}/{rep['lookups']} "
                  f"served (hit rate {rep['hit_rate']:.2f})  "
                  f"{rep['entries']} entries")
    if args.online:
        for w in sorted(workers, key=lambda w: w.wid):
            print(f"w{w.wid} {w.adapter.report()}")
    if governor is not None:
        now = max(w.clock.now for w in workers)
        g = governor.summary(now)
        print(f"shared budget ${g['budget_per_window']:.4f}/"
              f"{args.budget_window}s window  spend ${g['total_spend']:.6f}  "
              f"final lambda {g['lam']:.3g} (nominal {g['lam0']:.3g})  "
              f"tightened x{int(g['tightened'])} relaxed x{int(g['relaxed'])} "
              f"throttled x{governor.throttled}")
    t_end = max(w.clock.now for w in workers)
    _print_slo(slo, t_end)
    _save_obs(args, recorder, registry, profiler, flusher, now=t_end)
    return summary


def _run_plane_socket(args, ctx, trace, obs, raw_argv, mserver=None):
    """Multi-worker path over SocketTransport: real OS processes.

    This process is worker 0 AND the controller AND (by lowest-id
    election) the leader — the coordinator's updater reads the leader's
    engine directly, so leader/controller co-location is what lets socket
    mode run leader updates without shipping optimizer state over the
    wire. Workers 1..N-1 are ``repro.distributed.host`` subprocesses:
    each rebuilds identical seeded serving state from the forwarded argv,
    claims its pool shard (mesh-sharded parameters for owned members,
    evicted otherwise), and services protocol messages over
    length-prefixed TCP. Generate legs for a member the executing worker
    does not own hop to the owner as ``GENERATE`` messages; follower
    budget ops flow to the controller's shared ledger as ``LEDGER_OP``.
    """
    import json
    import os
    import subprocess

    from repro.distributed import (
        Coordinator, PlaneEvent, PoolDispatcher, ServingPlane,
        SharedBudgetLedger, SocketTransport, SyncConfig, TransportError,
        owner_of,
    )
    from repro.distributed import messages as M
    from repro.distributed.host import RemoteWorkerProxy
    from repro.distributed.messages import Message
    from repro.distributed.shard import shard_pool

    recorder, registry, profiler, flusher = obs
    slo = _make_slo(args, tracer=recorder)
    governor = None
    if args.budget > 0:
        governor = SharedBudgetLedger(args.budget, args.budget_window,
                                      lam0=args.lam)

    # Long conn timeout: follower processes connect BEFORE building their
    # engines, so frames queue in TCP buffers while training runs — the
    # first real exchange can lag the connect by minutes on a cold CPU.
    transport = SocketTransport(0, timeout=600.0)
    port = transport.listen()
    # Followers must import repro the same way this process did, even when
    # the driver was launched by path (no PYTHONPATH in the environment).
    import repro

    env = dict(os.environ)
    # __path__ (not __file__): repro is a plain src-layout package dir and
    # may be imported as a namespace package, where __file__ is None.
    src_root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env["PYTHONPATH"] = (src_root + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src_root)
    procs = [
        subprocess.Popen([sys.executable, "-m", "repro.distributed.host",
                          "--wid", str(wid), "--port", str(port),
                          "--serve-argv", json.dumps(raw_argv)],
                         env=env)
        for wid in range(1, args.workers)
    ]
    try:
        hellos = transport.accept(args.workers - 1, timeout=120.0)
        drift_proto = build_drift_proto(args, ctx)
        w0 = build_plane_worker(args, ctx, 0, governor, drift_proto,
                                recorder, slo)
        w0.ledger = governor        # follower LEDGER_OP messages land here
        shard_pool(w0.engine.pool, 0, args.workers)
        w0.scheduler.dispatcher = PoolDispatcher(0, args.workers,
                                                 w0.engine, transport)
        w0.bind(transport)
        names = [m.name for m in ctx.engine.pool]
        proxies = [
            RemoteWorkerProxy(wid, transport, member_names=names,
                              pid=int(hellos[wid].get("pid", -1)))
            for wid in range(1, args.workers)
        ]
        workers = [w0] + proxies
        pids = {0: os.getpid()}
        pids.update({p.wid: p.pid for p in proxies})
        print(f"socket plane: controller pid {pids[0]} port {port}  "
              + "  ".join(f"w{p.wid}:pid{p.pid}" for p in proxies))
        print("pool ownership: " + "  ".join(
            f"{names[mi]}->w{owner_of(mi, args.workers)}"
            for mi in range(len(names))))

        from repro.online import OnlineUpdateConfig
        coord = Coordinator(workers, SyncConfig(
            sync_every_s=args.sync_every, seed=args.seed,
            update=OnlineUpdateConfig(
                update_every=args.online_update_every)),
            transport=transport)
        events = []
        if args.crash_at is not None:
            events.append(
                PlaneEvent(args.crash_at, "crash", args.crash_worker))
            if args.rejoin_at is not None:
                events.append(
                    PlaneEvent(args.rejoin_at, "rejoin", args.crash_worker))
        # Fleet-wide obs drain, called by the plane at sync boundaries
        # (and once more after the run): incremental follower trace
        # segments are absorbed verbatim (keys pre-partitioned by the
        # followers' key_base), and follower registries are scraped over
        # METRICS_REQ so the live /metrics endpoint federates the fleet.
        # RPCs happen HERE, on the plane loop — never on the HTTP scrape
        # thread (the socket protocol is single-threaded lockstep).
        fleet_prom = {}

        def fleet_drain(now, force=False):
            for p in proxies:
                try:
                    if recorder is not None:
                        rep = transport.request(Message(
                            kind=M.TRACE_REQ, dst=p.wid,
                            payload={"force": bool(force)}))
                        recorder.absorb(
                            [tuple(e) for e in rep.payload["events"]])
                    if registry is not None:
                        rep = transport.request(Message(
                            kind=M.METRICS_REQ, dst=p.wid))
                        text = rep.payload.get("prom", "")
                        if text:
                            fleet_prom[p.wid] = text
                            if mserver is not None:
                                mserver.update_fleet(p.wid, text)
                except TransportError:
                    continue

        plane = ServingPlane(workers, coord, events=events, tracer=recorder,
                             flusher=flusher,
                             fleet_drain=(fleet_drain
                                          if recorder is not None
                                          or registry is not None
                                          else None))
        if registry is not None:
            from repro.obs import (
                register_plane_metrics, register_slo_metrics,
                register_stream_metrics,
            )

            register_plane_metrics(registry, plane)
            if slo is not None:
                register_slo_metrics(
                    registry, slo,
                    lambda: max(w.clock.now
                                for w in plane.workers.values()))
            if flusher is not None:
                register_stream_metrics(registry, flusher)
        summary = plane.run_trace(trace)
        summary["transport"] = "socket"
        summary["pids"] = pids
        summary["pool_owner"] = {
            names[mi]: owner_of(mi, args.workers)
            for mi in range(len(names))}

        # Final force-drain: whatever the incremental sync-boundary drains
        # have not collected yet (open trees, post-FINALIZE spans, the
        # last metrics state) is absorbed now so --trace-out and the
        # fleet exposition cover the whole run.
        if recorder is not None or registry is not None:
            fleet_drain(None, force=True)

        print(f"trace={args.trace} requests={args.requests} "
              f"seed={args.seed} workers={args.workers} transport=socket")
        print(plane.report(summary.get("duration_s")))
        # Only w0's serving components live in this process; each follower
        # prints its own cascade/semcache/adapter lines at shutdown.
        if args.cascade and w0.scheduler.cascade is not None:
            print(f"w0 {w0.scheduler.cascade.report()}")
        if args.semcache and w0.scheduler.semcache is not None:
            rep = w0.scheduler.semcache.report()
            print(f"w0 semcache: {rep['served']}/{rep['lookups']} "
                  f"served (hit rate {rep['hit_rate']:.2f})  "
                  f"{rep['entries']} entries")
        if args.online and w0.adapter is not None:
            print(f"w0 {w0.adapter.report()}")
        if governor is not None:
            now = max(w.clock.now for w in workers)
            g = governor.summary(now)
            print(f"shared budget ${g['budget_per_window']:.4f}/"
                  f"{args.budget_window}s window  "
                  f"spend ${g['total_spend']:.6f}  "
                  f"final lambda {g['lam']:.3g} (nominal {g['lam0']:.3g})  "
                  f"tightened x{int(g['tightened'])} "
                  f"relaxed x{int(g['relaxed'])} "
                  f"throttled x{governor.throttled}")
        t_end = max(w.clock.now for w in workers)
        _print_slo(slo, t_end)
        _save_obs(args, recorder, registry, profiler, flusher, now=t_end)
        if args.metrics_out and registry is not None and fleet_prom:
            from repro.obs import merge_prom_texts

            fleet_path = args.metrics_out + ".fleet.prom"
            own = registry.prometheus(
                deterministic=not args.trace_profile)
            with open(fleet_path, "w") as f:
                f.write(merge_prom_texts(
                    [own] + [fleet_prom[w] for w in sorted(fleet_prom)]))
            print(f"fleet metrics exposition written to {fleet_path} "
                  f"({1 + len(fleet_prom)} registries)")
        for p in proxies:
            try:
                transport.send(Message(kind=M.SHUTDOWN, dst=p.wid))
            except TransportError:
                pass
        return summary
    finally:
        transport.close()
        for pr in procs:
            try:
                pr.wait(timeout=60)
            except Exception:
                pr.kill()


if __name__ == "__main__":
    main()
