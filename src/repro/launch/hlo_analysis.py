"""Post-SPMD HLO analysis: collective inventory + roofline inputs.

``compiled.cost_analysis()`` provides FLOPs and bytes (with the documented
caveat that ``while`` bodies count once — see models/runtime_flags.py for how
the roofline probe removes that undercount). Collective traffic is NOT in
cost_analysis, so this module parses the optimized HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op's shape and replica-group size, converted to per-device ICI bytes with the
standard ring formulas:

    all-gather       out_bytes * (g-1)/g
    all-reduce       2 * bytes * (g-1)/g
    reduce-scatter   out_bytes * (g-1)         (out is the scattered shard)
    all-to-all       bytes * (g-1)/g
    collective-permute   bytes

Shapes in post-SPMD HLO are per-device, so the result is per-device traffic.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of possibly-tuple HLO shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> List[Dict]:
    """Inventory of collectives: kind, per-device result bytes, group size."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shape_str, kind, is_start = m.group(1), m.group(2), m.group(3)
        if is_start:
            # async start returns (operand, result, ...): count the largest
            # element once, not the whole tuple.
            sizes = [_shape_bytes(t.group(0))
                     for t in _SHAPE_RE.finditer(shape_str)]
            nbytes = max(sizes) if sizes else 0
        else:
            nbytes = _shape_bytes(shape_str)
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
        out.append({"kind": kind, "result_bytes": nbytes, "group": g})
    return out


def collective_bytes_per_device(collectives: List[Dict]) -> Tuple[float, Dict]:
    """ICI bytes per device + per-kind breakdown (ring formulas above)."""
    total = 0.0
    by_kind: Dict[str, float] = {}
    for c in collectives:
        g = max(c["group"], 1)
        b = float(c["result_bytes"])
        if c["kind"] == "collective-permute":
            contrib = b          # point-to-point: no replica_groups attr
        elif g == 1:
            contrib = 0.0
        elif c["kind"] == "all-gather":
            contrib = b * (g - 1) / g
        elif c["kind"] == "all-reduce":
            contrib = 2.0 * b * (g - 1) / g
        elif c["kind"] == "reduce-scatter":
            contrib = b * (g - 1)
        elif c["kind"] == "all-to-all":
            contrib = b * (g - 1) / g
        else:  # pragma: no cover
            contrib = b
        total += contrib
        by_kind[c["kind"]] = by_kind.get(c["kind"], 0.0) + contrib
    return total, by_kind


def summarize_compiled(compiled) -> Dict:
    """Everything the roofline needs from one compiled executable."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    mem = compiled.memory_analysis()
    text = compiled.as_text()
    coll = parse_collectives(text)
    coll_bytes, by_kind = collective_bytes_per_device(coll)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "argument_bytes_per_device": int(mem.argument_size_in_bytes),
        "output_bytes_per_device": int(mem.output_size_in_bytes),
        "temp_bytes_per_device": int(mem.temp_size_in_bytes),
        "alias_bytes_per_device": int(mem.alias_size_in_bytes),
        "peak_bytes_per_device": int(
            mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes
        ),
        "collective_bytes_per_device": coll_bytes,
        "collective_breakdown": by_kind,
        "n_collectives": len(coll),
    }
