"""Sharding rules: parameters (2-D TP x FSDP), activations, caches, batches.

Strategy (DESIGN.md §6):
  * Tensor parallel ("model" axis): head/ff/expert/vocab dimension of every
    projection; experts for MoE; d_inner for Mamba/mLSTM value paths.
  * FSDP ("data" axis, plus "pod" folded in when present): the complementary
    weight dimension. Optimizer moments mirror param specs => ZeRO-3.
  * Activations: batch over (pod, data); train shards heads/ff over "model",
    decode shards the KV-cache *sequence* over "model" (works for any
    kv-head count; XLA lowers the softmax over the sharded axis to the
    flash-decoding two-pass combine).
  * sLSTM: fully replicated params (full recurrent coupling is TP-hostile;
    the block is small) — data parallel only.

Param rules dispatch on (block spec, leaf path, rank) resolved through the
ArchConfig layer plan, because leaf names alone are ambiguous (mLSTM and
attention both have "wq").
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (
    ATTN, MAMBA, MLSTM, SLSTM, XATTN, ArchConfig, LayerSpec,
)
from repro.common.tree import flatten_with_paths


def batch_axes(mesh: Mesh):
    """Mesh axes carrying the batch dim: ("pod","data") on the 2-pod mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def fsdp_axis(mesh: Mesh):
    """Weight-sharding data axis (ZeRO): pod folded in when present."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


# ---------------------------------------------------------------------------
# Activation logical-axis rule tables (consumed by models.sharding_ctx)
# ---------------------------------------------------------------------------

def train_rules(mesh: Mesh) -> Dict[str, Any]:
    # NOTE: heads/kv_heads are deliberately UNCONSTRAINED: kv-head counts
    # (8) below the 16-way model axis force GSPMD into "involuntary full
    # rematerialization" replication copies when pinned. Letting sharding
    # propagate from the (model-sharded) projection weights avoids the
    # copies entirely (verified on qwen3 train_4k: peak memory 15.5 -> see
    # EXPERIMENTS.md §Dry-run).
    return {
        "batch": batch_axes(mesh),
        "seq": None,
        "embed": None,
        "heads": None,
        "kv_heads": None,
        "ff": "model",
        "ssm_inner": "model",
        "expert": "model",
        "cache_seq": None,
    }


def decode_rules(mesh: Mesh) -> Dict[str, Any]:
    return {
        "batch": batch_axes(mesh),
        "seq": None,
        "embed": None,
        "heads": None,
        "kv_heads": None,
        "ff": "model",
        "ssm_inner": "model",
        "expert": "model",
        "cache_seq": "model",     # sequence-sharded KV (flash-decoding style)
    }


# ---------------------------------------------------------------------------
# Parameter sharding
# ---------------------------------------------------------------------------

def _attn_param_spec(name: str, rank: int, dp) -> P:
    if name in ("wq", "wk", "wv"):
        return P(dp, "model")
    if name == "wo":
        return P("model", dp)
    if name in ("bq", "bk", "bv"):
        return P("model")
    if name == "w_proj":                      # modality projector (Fd, D)
        return P(None, "model")
    return P(*([None] * rank))                # norms etc.


def _mamba_param_spec(name: str, rank: int, dp) -> P:
    table = {
        "in_proj": P(dp, "model"),
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "x_proj": P("model", None),
        "dt_proj": P(None, "model"),
        "dt_bias": P("model"),
        "A_log": P("model", None),
        "D": P("model"),
        "out_proj": P("model", dp),
    }
    return table.get(name, P(*([None] * rank)))


def _mlstm_param_spec(name: str, rank: int, dp) -> P:
    table = {
        "up_proj": P(dp, "model"),
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        # Block-diag (H, dh, dh) per-head projections: q,k shard the
        # contraction dh (outputs replicated, as the state math wants);
        # v shards its OUTPUT dh so the matrix state C shards on dv.
        # Replicating these put >1B params (x10 bytes of Adam) per chip.
        "wq": P(None, "model", None),
        "wk": P(None, "model", None),
        "wv": P(None, None, "model"),
        "w_igate": P("model", None),
        "w_fgate": P("model", None),
        "skip": P("model"),
        "down_proj": P("model", dp),
    }
    return table.get(name, P(*([None] * rank)))


def _slstm_param_spec(name: str, rank: int, dp) -> P:
    # Recurrent coupling is TP-hostile: keep cell *activations* replicated,
    # but shard the big input projection on its contraction dim (memory).
    if name == "w":
        return P("model", None)
    if name == "ff_up":
        return P(dp, "model")
    if name == "ff_down":
        return P("model", dp)
    return P(*([None] * rank))


def _ffn_param_spec(name: str, rank: int, dp) -> P:
    if rank == 3:                              # MoE expert-stacked weights
        if name in ("w_gate", "w_up"):
            return P("model", dp, None)        # (E, D, F): experts on model
        if name == "w_down":
            return P("model", None, dp)        # (E, F, D)
    if name in ("w_gate", "w_up", "ff_up"):
        return P(dp, "model")
    if name in ("w_down", "ff_down"):
        return P("model", dp)
    if name == "router":
        return P(None, None)                   # small; replicate
    return P(*([None] * rank))


def _block_param_spec(spec: LayerSpec, sub: Tuple[str, ...], rank: int, dp) -> P:
    """sub e.g. ("mixer", "wq") or ("ffn", "shared", "w_gate") or ("norm1","scale")."""
    head, name = sub[0], sub[-1]
    if head in ("norm1", "norm2"):
        return P(*([None] * rank))
    if head == "mixer":
        if name in ("scale",):                 # q_norm/k_norm/proj_norm
            return P(*([None] * rank))
        if spec.mixer in (ATTN, XATTN):
            return _attn_param_spec(name, rank, dp)
        if spec.mixer == MAMBA:
            return _mamba_param_spec(name, rank, dp)
        if spec.mixer == MLSTM:
            return _mlstm_param_spec(name, rank, dp)
        if spec.mixer == SLSTM:
            return _slstm_param_spec(name, rank, dp)
    if head == "ffn":
        if len(sub) >= 3 and sub[1] == "shared":
            # Shared expert = plain MLP.
            if name in ("w_gate", "w_up"):
                return P(dp, "model")
            if name == "w_down":
                return P("model", dp)
        return _ffn_param_spec(name, rank, dp)
    return P(*([None] * rank))


def param_spec(cfg: ArchConfig, mesh: Mesh, path: str, rank: int) -> P:
    """PartitionSpec for one parameter leaf by its tree path."""
    dp = fsdp_axis(mesh)
    parts = tuple(path.split("/"))
    if parts[0] == "embedding":
        if parts[1] == "table":                # (V, D)
            return P("model", dp)
        if parts[1] == "head":                 # (D, V)
            return P(dp, "model")
    if parts[0] == "final_norm":
        return P(*([None] * rank))
    if parts[0] in ("pattern", "remainder"):
        pos = int(parts[1])
        spec = (cfg.pattern[pos] if parts[0] == "pattern" else cfg.remainder[pos])
        inner = _block_param_spec(spec, parts[2:], rank if parts[0] == "remainder" else rank - 1, dp)
        if parts[0] == "pattern":              # stacked: leading repeat axis
            return P(None, *inner)
        return inner
    return P(*([None] * rank))


def param_shardings(cfg: ArchConfig, mesh: Mesh, abstract_params: Any):
    """NamedSharding tree matching ``abstract_params``."""
    flat = flatten_with_paths(abstract_params)
    specs = {
        path: NamedSharding(mesh, param_spec(cfg, mesh, path, len(leaf.shape)))
        for path, leaf in flat.items()
    }
    leaves, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    out = []
    for path, leaf in leaves:
        from repro.common.tree import _path_str
        out.append(specs[_path_str(path)])
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Cache sharding (decode/prefill)
# ---------------------------------------------------------------------------
#
# jit *argument* shardings must divide their dimensions exactly (GSPMD only
# pads intermediates), so every rule here checks divisibility and falls back:
#   * batch=1 (long_500k): KV sequence shards over ALL mesh axes instead;
#   * cross-attention media caches (1601 tokens): batch-sharded only.


def _axes_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def _divisible(n: int, mesh: Mesh, ax) -> bool:
    return ax is not None and n % _axes_size(mesh, ax) == 0


def _cache_leaf_spec(
    cfg: ArchConfig, mesh: Mesh, spec: Optional[LayerSpec], name: str,
    shape: Tuple[int, ...],
) -> P:
    b_ax = batch_axes(mesh)
    rank = len(shape)
    bsz = shape[0] if rank >= 1 else 1
    bspec = b_ax if _divisible(bsz, mesh, b_ax) else None

    if name in ("k", "v") and rank == 4:
        if spec is not None and spec.mixer == XATTN:
            return P(bspec, None, None, None)       # media cache: batch only
        length = shape[1]
        if bspec is None:
            every = tuple(mesh.axis_names)          # single long request
            if _divisible(length, mesh, every):
                return P(None, every, None, None)
        seq_ax = "model" if _divisible(length, mesh, "model") else None
        return P(bspec, seq_ax, None, None)
    if name == "slot_pos":
        return P(*([None] * rank))
    if name == "h" and rank == 3:                   # mamba state (B, di, ds)
        return P(bspec, "model" if _divisible(shape[1], mesh, "model") else None, None)
    if name == "conv" and rank == 3:                # (B, dc-1, di)
        return P(bspec, None, "model" if _divisible(shape[2], mesh, "model") else None)
    if name == "C" and rank == 4:                   # mlstm (B, H, dk, dv)
        return P(bspec, None, None,
                 "model" if _divisible(shape[3], mesh, "model") else None)
    if name == "n" and rank == 3:                   # mlstm (B, H, dk)
        return P(bspec, None, None)
    if rank >= 1:
        return P(bspec, *([None] * (rank - 1)))     # slstm states etc.
    return P()


def cache_shardings(cfg: ArchConfig, mesh: Mesh, abstract_caches: Any):
    def one(path_str: str, leaf):
        parts = path_str.split("/")
        stacked = parts[0] == "pattern"
        spec = None
        if parts[0] in ("pattern", "remainder"):
            pos = int(parts[1])
            plan = cfg.pattern if parts[0] == "pattern" else cfg.remainder
            spec = plan[pos]
        name = parts[-1]
        shape = leaf.shape[1:] if stacked else leaf.shape
        inner = _cache_leaf_spec(cfg, mesh, spec, name, tuple(shape))
        return NamedSharding(mesh, P(None, *inner) if stacked else inner)

    leaves, treedef = jax.tree_util.tree_flatten_with_path(abstract_caches)
    from repro.common.tree import _path_str
    out = [one(_path_str(p), leaf) for p, leaf in leaves]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Batch input sharding
# ---------------------------------------------------------------------------

def batch_shardings(mesh: Mesh, batch_tree: Any):
    b = batch_axes(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if not _divisible(leaf.shape[0], mesh, b):
            return NamedSharding(mesh, P(*([None] * leaf.ndim)))
        return NamedSharding(mesh, P(b, *([None] * (leaf.ndim - 1))))

    return jax.tree.map(one, batch_tree)
