"""Step-function factories shared by the dry-run and the real drivers.

Each factory closes over (cfg, mesh) and installs the right logical-axis
rule table *inside* the traced body (so the same model code shards under the
production mesh and runs unsharded in unit tests).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.sharding import decode_rules, train_rules
from repro.models import lm as lm_mod
from repro.models.sharding_ctx import logical_sharding
from repro.training.optim import AdamConfig, adam_update

TRAIN_ADAM = AdamConfig(lr=3e-4, b1=0.9, b2=0.95, weight_decay=0.1)


def make_train_step(
    cfg: ArchConfig,
    mesh=None,
    adam_cfg: AdamConfig = TRAIN_ADAM,
    microbatch: int = 1,
    rules_override: Optional[Dict] = None,
):
    """(params, opt_state, batch) -> (loss, params, opt_state).

    ``microbatch > 1`` runs gradient accumulation: the global batch is split
    into ``microbatch`` sequential chunks under ``lax.scan``, dividing the
    live activation footprint by the same factor (a §Perf memory lever).
    """
    rules = train_rules(mesh) if mesh is not None else None
    if rules is not None and rules_override:
        rules = {**rules, **rules_override}

    def loss_fn(params, batch):
        return lm_mod.lm_loss(
            cfg, params, batch["tokens"], batch["labels"],
            media=batch.get("media"), attn_mask=batch.get("attn_mask"),
        )

    def grad_fn(params, batch):
        if microbatch <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def split(x):
            b = x.shape[0]
            assert b % microbatch == 0, (b, microbatch)
            return x.reshape(microbatch, b // microbatch, *x.shape[1:])

        mbs = {k: split(v) for k, v in batch.items()}

        def body(carry, mb):
            loss_sum, gsum = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            gsum = jax.tree.map(lambda a, b_: a + b_, gsum, g)
            return (loss_sum + l, gsum), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
        (loss_sum, gsum), _ = jax.lax.scan(
            body, (jnp.float32(0.0), zeros), mbs
        )
        inv = 1.0 / microbatch
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, gsum)

    def train_step(params, opt_state, batch):
        ctx = (
            logical_sharding(mesh, rules) if rules is not None
            else _null_ctx()
        )
        with ctx:
            loss, grads = grad_fn(params, batch)
            params, opt_state = adam_update(adam_cfg, grads, opt_state, params)
        return loss, params, opt_state

    return train_step


def make_prefill_step(cfg: ArchConfig, mesh=None):
    """(params, batch{tokens, caches[, media]}) -> (last_logits, caches)."""
    rules = train_rules(mesh) if mesh is not None else None

    def prefill_step(params, batch):
        ctx = (
            logical_sharding(mesh, rules) if rules is not None
            else _null_ctx()
        )
        with ctx:
            return lm_mod.apply_lm_prefill(
                cfg, params, batch["tokens"], batch["caches"],
                media=batch.get("media"),
            )

    return prefill_step


def make_decode_step(cfg: ArchConfig, mesh=None):
    """(params, batch{token, caches, pos}) -> (logits, caches)."""
    rules = decode_rules(mesh) if mesh is not None else None

    def decode_step(params, batch):
        ctx = (
            logical_sharding(mesh, rules) if rules is not None
            else _null_ctx()
        )
        with ctx:
            return lm_mod.apply_lm_decode(
                cfg, params, batch["token"], batch["caches"], batch["pos"]
            )

    return decode_step


class _null_ctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def abstract_opt_state(cfg: ArchConfig, abstract_params, adam_cfg=TRAIN_ADAM):
    from repro.training.optim import adam_init

    return jax.eval_shape(functools.partial(adam_init, adam_cfg), abstract_params)
