import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, proving the distribution config is coherent without
real hardware.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
    ... --probe-repeats 2   (roofline probe: inner loops unrolled, see
                             models/runtime_flags.py)

Writes one JSON record per run under --out-dir (default reports/dryrun/).
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ArchConfig
from repro.configs.shapes import SHAPES, ShapeCfg, input_specs, shape_applicable
from repro.launch.hlo_analysis import summarize_compiled
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.launch.sharding import (
    batch_shardings, cache_shardings, param_shardings,
)
from repro.launch.steps import (
    abstract_opt_state, make_decode_step, make_prefill_step, make_train_step,
)
from repro.models import lm as lm_mod
from repro.models import runtime_flags

PARAM_DTYPE = jnp.bfloat16


def probe_config(cfg: ArchConfig, n_repeats: int) -> ArchConfig:
    """Shrink to n_repeats pattern repeats (roofline probe)."""
    return dataclasses.replace(
        cfg,
        n_repeats=n_repeats,
        n_layers=len(cfg.pattern) * n_repeats + len(cfg.remainder),
    )


def lower_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    probe_repeats: int = 0,
    donate: bool = True,
    microbatch: int = 1,
    moment_dtype: str = "fp32",
    seq_shard: bool = False,
    xlstm_gather: bool = False,
    variant: str = "",
):
    """Lower + compile one (arch, shape, mesh). Returns the report dict."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "long_500k requires sub-quadratic decode "
                          "(DESIGN.md §5)"}
    if probe_repeats:
        cfg = probe_config(cfg, probe_repeats)

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    abstract_params = lm_mod.abstract_params(cfg, dtype=PARAM_DTYPE)
    p_shardings = param_shardings(cfg, mesh, abstract_params)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        import dataclasses as _dc
        from repro.launch.steps import TRAIN_ADAM
        adam_cfg = _dc.replace(
            TRAIN_ADAM,
            moment_dtype=jnp.bfloat16 if moment_dtype == "bf16" else jnp.float32,
        )
        rules_override = {}
        if seq_shard:
            rules_override["seq"] = "model"
        if xlstm_gather:
            rules_override["xlstm_gather_params"] = True
        rules_override = rules_override or None
        step = make_train_step(cfg, mesh, adam_cfg, microbatch=microbatch,
                               rules_override=rules_override)
        opt_abs = abstract_opt_state(cfg, abstract_params, adam_cfg)
        opt_shardings = param_shardings(
            cfg, mesh, opt_abs.m
        )  # moments mirror params (ZeRO-3)
        from repro.training.optim import AdamState
        from jax.sharding import NamedSharding, PartitionSpec as P
        opt_sh = AdamState(
            step=NamedSharding(mesh, P()), m=opt_shardings, v=opt_shardings
        )
        in_sh = (p_shardings, opt_sh, batch_shardings(mesh, specs))
        args = (abstract_params, opt_abs, specs)
        jitted = jax.jit(
            step, in_shardings=in_sh,
            donate_argnums=(0, 1) if donate else (),
        )
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, mesh)
        c_sh = cache_shardings(cfg, mesh, specs["caches"])
        b_sh = {"tokens": batch_shardings(mesh, specs["tokens"]),
                "caches": c_sh}
        if "media" in specs:
            b_sh["media"] = batch_shardings(mesh, specs["media"])
        args = (abstract_params, specs)
        jitted = jax.jit(
            step, in_shardings=(p_shardings, b_sh),
            donate_argnums=(1,) if donate else (),
        )
    else:  # decode
        step = make_decode_step(cfg, mesh)
        from jax.sharding import NamedSharding, PartitionSpec as P
        b_sh = {
            "token": batch_shardings(mesh, specs["token"]),
            "caches": cache_shardings(cfg, mesh, specs["caches"]),
            "pos": NamedSharding(mesh, P()),
        }
        args = (abstract_params, specs)
        jitted = jax.jit(
            step, in_shardings=(p_shardings, b_sh),
            donate_argnums=(1,) if donate else (),
        )

    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        summary = summarize_compiled(compiled)

    report = {
        "arch": arch,
        "shape": shape_name,
        "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": mesh_chips(mesh),
        "probe_repeats": probe_repeats,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        **summary,
    }
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--probe-repeats", type=int, default=0,
                    help="roofline probe: n pattern repeats, inner loops unrolled")
    ap.add_argument("--out-dir", default="reports/dryrun")
    ap.add_argument("--no-donate", action="store_true")
    # §Perf hillclimb levers (train shapes):
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--moment-dtype", choices=["fp32", "bf16"], default="fp32")
    ap.add_argument("--seq-shard", action="store_true",
                    help="shard train activations' seq dim over 'model'")
    ap.add_argument("--xlstm-gather", action="store_true",
                    help="ZeRO-3 gathered-weights mode for xLSTM blocks")
    ap.add_argument("--variant", default="",
                    help="tag appended to the output file name")
    args = ap.parse_args()

    pairs = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    os.makedirs(args.out_dir, exist_ok=True)
    failures = 0
    for arch, shape in pairs:
        tag = f"{arch}__{shape}__{'2x16x16' if args.multi_pod else '16x16'}"
        if args.probe_repeats:
            tag += f"__probe{args.probe_repeats}"
        if args.variant:
            tag += f"__{args.variant}"
        out_path = os.path.join(args.out_dir, tag + ".json")
        try:
            ctx = (runtime_flags.unroll_inner() if args.probe_repeats
                   else _Null())
            with ctx:
                rep = lower_one(
                    arch, shape,
                    multi_pod=args.multi_pod,
                    probe_repeats=args.probe_repeats,
                    donate=not args.no_donate,
                    microbatch=args.microbatch,
                    moment_dtype=args.moment_dtype,
                    seq_shard=args.seq_shard,
                    xlstm_gather=args.xlstm_gather,
                    variant=args.variant,
                )
        except Exception as e:  # noqa: BLE001 — record and continue
            rep = {"arch": arch, "shape": shape, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            failures += 1
        with open(out_path, "w") as f:
            json.dump(rep, f, indent=2)
        status = rep["status"]
        extra = ""
        if status == "ok":
            extra = (f"flops={rep['flops']:.3e} "
                     f"coll={rep['collective_bytes_per_device']:.3e}B "
                     f"peak={rep['peak_bytes_per_device']/2**30:.2f}GiB "
                     f"compile={rep['compile_s']}s")
        elif status == "error":
            extra = rep["error"][:200]
        print(f"[dryrun] {tag}: {status} {extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run failures")


class _Null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
