"""LM training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --smoke --steps 50 --batch 8 --seq 128

``--smoke`` uses the reduced per-arch config (CPU-runnable); without it the
full config is used (requires the production mesh / real hardware). The
~100M end-to-end example (examples/train_lm.py) drives this module's API.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config, get_smoke_config
from repro.data.lm_data import MarkovCorpus
from repro.launch.steps import TRAIN_ADAM, make_train_step
from repro.models import lm as lm_mod
from repro.training.optim import AdamConfig, adam_init


def train_loop(
    cfg,
    *,
    steps: int,
    batch: int,
    seq: int,
    mesh=None,
    lr: float = 3e-4,
    seed: int = 0,
    log_every: int = 10,
    checkpoint_path: Optional[str] = None,
    media_fn=None,
    var_len: bool = False,
):
    """Returns (params, list of losses).

    ``var_len`` trains on variable-length left-padded batches (the
    corpus's ``padded_batches``): the pad mask threads through
    ``lm_loss(attn_mask=)`` so CE and MoE aux/capacity accounting see only
    real tokens — the serving-side masked-compute guarantees, exercised at
    training time.
    """
    adam_cfg = AdamConfig(lr=lr, b1=0.9, b2=0.95, weight_decay=0.1, t_max=steps)
    params = lm_mod.init_lm(jax.random.key(seed), cfg)
    opt_state = adam_init(adam_cfg, params)
    step_fn = jax.jit(make_train_step(cfg, mesh, adam_cfg), donate_argnums=(0, 1))

    corpus = MarkovCorpus(cfg.vocab_size, seed=seed)
    if var_len:
        batches = corpus.padded_batches(batch, seq, seed=seed + 1)
    else:
        batches = corpus.batches(batch, seq, seed=seed + 1)
    losses = []
    t0 = time.time()
    for i in range(steps):
        if var_len:
            tokens, labels, mask = next(batches)
        else:
            tokens, labels = next(batches)
            mask = None
        b = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        if mask is not None:
            b["attn_mask"] = jnp.asarray(mask)
        if media_fn is not None:
            b["media"] = media_fn(i)
        loss, params, opt_state = step_fn(params, opt_state, b)
        losses.append(float(loss))
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:5d}  loss {losses[-1]:.4f}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    if checkpoint_path:
        save_checkpoint(checkpoint_path, params, {"arch": cfg.name, "steps": steps})
        print(f"saved checkpoint to {checkpoint_path}")
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--var-len", action="store_true",
                    help="variable-length left-padded batches with pad "
                         "masks (exercises masked CE + MoE accounting)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    media_fn = None
    if cfg.arch_type == "vlm" and cfg.n_frontend_tokens:
        key = jax.random.key(7)
        media = jax.random.normal(
            key, (args.batch, cfg.n_frontend_tokens, cfg.frontend_dim)
        )
        media_fn = lambda i: media
    _, losses = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=args.lr, checkpoint_path=args.checkpoint, media_fn=media_fn,
        var_len=args.var_len,
    )
    print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
