"""Production mesh + TPU v5e hardware constants.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before *any* jax
initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 v5e pod mesh (data, model); 2 pods adds a leading "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh for CPU integration tests (requires that many devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# --- TPU v5e per-chip constants (assignment-specified) ----------------------
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW_PER_LINK = 50e9            # bytes/s/link

def mesh_chips(mesh) -> int:
    return mesh.devices.size
