"""Render markdown tables for EXPERIMENTS.md from reports/dryrun + roofline.

    PYTHONPATH=src python tools/make_tables.py dryrun|roofline
"""
from __future__ import annotations

import glob
import json
import os
import sys

ARCH_ORDER = [
    "musicgen-large", "xlstm-1.3b", "granite-moe-1b-a400m",
    "jamba-1.5-large-398b", "gemma3-27b", "qwen1.5-4b", "qwen3-0.6b",
    "llama4-maverick-400b-a17b", "llama-3.2-vision-90b", "granite-3-8b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load_all(directory="reports/dryrun"):
    out = {}
    for path in glob.glob(os.path.join(directory, "*.json")):
        with open(path) as f:
            r = json.load(f)
        tag = os.path.basename(path)[: -len(".json")]
        out[tag] = r
    return out


def dryrun_table():
    recs = _load_all()
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### Mesh {mesh} ({256 if mesh=='16x16' else 512} chips)\n")
        print("| arch | shape | status | HLO GFLOP/dev | coll GB/dev | "
              "peak GiB/dev | args GiB | compile s |")
        print("|---|---|---|---|---|---|---|---|")
        for arch in ARCH_ORDER:
            for shape in SHAPE_ORDER:
                r = recs.get(f"{arch}__{shape}__{mesh}")
                if r is None:
                    print(f"| {arch} | {shape} | MISSING | | | | | |")
                    continue
                if r["status"] == "skipped":
                    print(f"| {arch} | {shape} | skip (full attention) "
                          f"| — | — | — | — | — |")
                    continue
                if r["status"] != "ok":
                    print(f"| {arch} | {shape} | ERROR | | | | | |")
                    continue
                print(
                    f"| {arch} | {shape} | ok "
                    f"| {r['flops']/1e9:.1f} "
                    f"| {r['collective_bytes_per_device']/1e9:.2f} "
                    f"| {r['peak_bytes_per_device']/2**30:.2f} "
                    f"| {r['argument_bytes_per_device']/2**30:.2f} "
                    f"| {r['compile_s']} |"
                )


def roofline_table():
    with open("reports/roofline.json") as f:
        rows = json.load(f)
    idx = {(r["arch"], r["shape"]): r for r in rows}
    print("| arch | shape | compute s | memory s | collective s | dominant | "
          "MODEL/HLO | peak GiB | probe |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = idx.get((arch, shape))
            if r is None:
                continue
            print(
                f"| {arch} | {shape} "
                f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
                f"| {r['collective_s']:.2e} | **{r['dominant']}** "
                f"| {r['useful_ratio']:.2f} | {r['peak_gib']:.1f} "
                f"| {'y' if r['probe_corrected'] else 'RAW'} |"
            )


if __name__ == "__main__":
    {"dryrun": dryrun_table, "roofline": roofline_table}[sys.argv[1]]()
