"""Inspect / validate / filter Chrome-trace JSON emitted by the serving
runtime (``--trace-out`` on ``repro.launch.serve``, ``tools/obs_smoke.py``).

The files are already Perfetto-loadable (https://ui.perfetto.dev — open the
JSON directly, or chrome://tracing). This CLI covers what a UI doesn't:

    # validate schema + span-tree well-formedness, print a summary
    PYTHONPATH=src python tools/trace_export.py trace.json

    # one request's full span tree (tid = trace key + 1)
    PYTHONPATH=src python tools/trace_export.py trace.json --request 7

    # re-emit a filtered trace (one worker / selected categories) for
    # loading into Perfetto, pretty-printed for diffing
    PYTHONPATH=src python tools/trace_export.py trace.json \\
        --worker 0 --cat request,cascade -o filtered.json --pretty

    # stitch a streaming run's rotated segments (--scrape-every) back
    # into one valid Chrome trace; accepts the obs dir (reads its
    # manifest) or explicit segment files in flush order
    PYTHONPATH=src python tools/trace_export.py concat obs_dir \\
        -o full.json
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs import (
    request_trees,
    trace_summary,
    validate_chrome_trace,
    validate_span_tree,
)


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def filter_doc(doc: dict, worker=None, cats=None) -> dict:
    """Subset a trace document; metadata rows follow surviving workers."""
    out = []
    for ev in doc["traceEvents"]:
        if ev.get("ph") == "M":
            if worker is None or ev.get("pid") == worker:
                out.append(ev)
            continue
        if worker is not None and ev.get("pid") != worker:
            continue
        if cats is not None and ev.get("cat") not in cats:
            continue
        out.append(ev)
    return {**doc, "traceEvents": out}


def rpc_index(doc: dict) -> dict:
    """rpc link id -> {"client": span, "server": span} over the doc's
    cross-process rpc spans (both sides of one RPC share args["rpc"])."""
    idx = {}
    for ev in doc["traceEvents"]:
        if ev.get("ph") != "X" or ev.get("name") != "rpc":
            continue
        a = ev.get("args") or {}
        if a.get("rpc") is None:
            continue
        idx.setdefault(a["rpc"], {})[a.get("side")] = ev
    return idx


def print_request(doc: dict, key: int) -> int:
    trees = request_trees(doc)
    tid = key + 1
    if tid not in trees:
        print(f"no events for request trace key {key} (tid {tid})")
        return 1
    t = trees[tid]
    rpcs = rpc_index(doc)
    for ev in sorted(t["events"], key=lambda e: (e["ts"], e.get("dur", 0))):
        dur = f"  dur={ev['dur'] / 1e3:.3f}ms" if "dur" in ev else ""
        args = f"  {ev['args']}" if ev.get("args") else ""
        print(f"  {ev['ts'] / 1e3:10.3f}ms  w{ev['pid']}  "
              f"[{ev['cat']}] {ev['name']}{dur}{args}")
        # Follow the span's rpc flow link across process boundaries: the
        # remote leg's server-side span lives on another pid's runtime
        # track, not in this request tree.
        link = (ev.get("args") or {}).get("rpc")
        if link is not None and ev.get("name") != "rpc":
            pair = rpcs.get(link, {})
            for side in ("client", "server"):
                leg = pair.get(side)
                if leg is not None:
                    print(f"      ↳ rpc#{link} {side} w{leg['pid']}  "
                          f"{leg['ts'] / 1e3:.3f}ms  "
                          f"dur={leg.get('dur', 0) / 1e3:.3f}ms  "
                          f"kind={leg['args'].get('kind')}")
            if "server" not in pair:
                print(f"      ↳ rpc#{link} server span MISSING "
                      f"(dangling flow link)")
    root = t["root"]
    if root is not None:
        print(f"request root: status={root.get('args', {}).get('status')}  "
              f"legs={root.get('args', {}).get('legs')}  "
              f"span {root['ts'] / 1e3:.3f}ms -> "
              f"{(root['ts'] + root['dur']) / 1e3:.3f}ms")
    return 0


def main_concat(argv) -> int:
    import os

    from repro.obs import concat_segments
    from repro.obs.stream import segment_paths

    ap = argparse.ArgumentParser(
        prog="trace_export.py concat",
        description="stitch rotated trace segments into one Chrome trace")
    ap.add_argument("inputs", nargs="+",
                    help="an obs segment directory (reads manifest.json) "
                         "or trace-*.json segment files in flush order")
    ap.add_argument("-o", "--out", default=None,
                    help="write the stitched trace here (default: stdout "
                         "summary only)")
    ap.add_argument("--pretty", action="store_true",
                    help="indent the output JSON")
    ap.add_argument("--no-validate", action="store_true",
                    help="skip schema/span-tree validation")
    args = ap.parse_args(argv)

    if len(args.inputs) == 1 and os.path.isdir(args.inputs[0]):
        paths = segment_paths(args.inputs[0])
    else:
        paths = args.inputs
    if not paths:
        print("no trace segments found")
        return 1
    doc = concat_segments(paths)

    rc = 0
    if not args.no_validate:
        schema = validate_chrome_trace(doc)
        tree = validate_span_tree(doc)
        for err in schema[:20]:
            print(f"schema: {err}")
        for err in tree[:20]:
            print(f"span-tree: {err}")
        if schema or tree:
            rc = 1
        else:
            print("valid chrome trace, well-formed span tree")

    summ = trace_summary(doc)
    print(f"{len(paths)} segments -> {summ['events']} events  "
          f"workers {summ['workers']}  requests {summ['requests']} "
          f"({summ['finalized']} finalized)")
    if doc["otherData"].get("drops"):
        d = doc["otherData"]["drops"]
        print(f"drops: {d.get('requests_sampled_out', 0)} trees sampled "
              f"out, {d.get('requests_shed', 0)} shed by the cap")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(doc, f, sort_keys=True,
                      indent=2 if args.pretty else None,
                      separators=None if args.pretty else (",", ":"))
        print(f"wrote stitched trace -> {args.out}")
    return rc


def main() -> int:
    if len(sys.argv) > 1 and sys.argv[1] == "concat":
        return main_concat(sys.argv[2:])
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome-trace JSON file")
    ap.add_argument("--request", type=int, default=None, metavar="KEY",
                    help="print one request's span tree (its trace key)")
    ap.add_argument("--worker", type=int, default=None,
                    help="keep only this worker's events")
    ap.add_argument("--cat", default=None,
                    help="comma-separated categories to keep")
    ap.add_argument("-o", "--out", default=None,
                    help="write the (filtered) trace JSON here")
    ap.add_argument("--pretty", action="store_true",
                    help="indent the output JSON")
    ap.add_argument("--no-validate", action="store_true",
                    help="skip schema/span-tree validation")
    args = ap.parse_args()

    doc = load(args.trace)

    rc = 0
    if not args.no_validate:
        schema = validate_chrome_trace(doc)
        tree = validate_span_tree(doc)
        for err in schema[:20]:
            print(f"schema: {err}")
        for err in tree[:20]:
            print(f"span-tree: {err}")
        if schema or tree:
            rc = 1
        else:
            print("valid chrome trace, well-formed span tree")

    if args.request is not None:
        return print_request(doc, args.request) or rc

    summ = trace_summary(doc)
    print(f"label: {doc.get('otherData', {}).get('label')}  "
          f"deterministic: {doc.get('otherData', {}).get('deterministic')}")
    print(f"{summ['events']} events  workers {summ['workers']}  "
          f"requests {summ['requests']} ({summ['finalized']} finalized)")
    by = ", ".join(f"{k}={v}" for k, v in sorted(summ["by_name"].items()))
    print(f"by name: {by}")
    rpcs = rpc_index(doc)
    if rpcs:
        n_cli = sum(1 for p in rpcs.values() if "client" in p)
        n_srv = sum(1 for p in rpcs.values() if "server" in p)
        linked = sum(1 for p in rpcs.values()
                     if "client" in p and "server" in p)
        cross = sum(1 for p in rpcs.values()
                    if "client" in p and "server" in p
                    and p["client"]["pid"] != p["server"]["pid"])
        print(f"rpc: {n_cli} client / {n_srv} server spans  "
              f"{linked} linked pairs ({cross} cross-worker)")

    if args.out:
        cats = set(args.cat.split(",")) if args.cat else None
        filtered = filter_doc(doc, worker=args.worker, cats=cats)
        with open(args.out, "w") as f:
            json.dump(filtered, f, sort_keys=True,
                      indent=2 if args.pretty else None,
                      separators=None if args.pretty else (",", ":"))
        n = sum(1 for e in filtered["traceEvents"] if e.get("ph") != "M")
        print(f"wrote {n} events -> {args.out}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
