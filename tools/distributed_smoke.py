"""Distributed serving smoke: the socket transport against real processes.

Runs the seeded serve driver twice on the SAME argv — once on the
in-process ``LocalTransport`` plane, once on ``--transport socket``
(controller + N-1 follower OS processes, mesh-sharded pool, shared
ledger over ``LEDGER_OP``) — and asserts the message-passing refactor's
core contract:

  * **parity** — both planes converge to the same final router version
    on every worker and produce matching deterministic telemetry rollups
    (completed / spend / per-member counts / sync + merge + update
    counters). Only wall-measured latency percentiles may differ.
  * **real processes** — the socket run reports >= ``--workers`` distinct
    OS pids (the controller plus one per follower), proving the legs
    crossed process boundaries rather than a loopback.
  * **sharded pool** — the socket summary's member->owner layout covers
    every pool member, each owned by a valid worker.
  * **artifacts** — both summaries plus the controller's merged fleet
    trace (followers folded in via ``TRACE_REQ``) land in ``--out-dir``
    for CI upload.

    PYTHONPATH=src python tools/distributed_smoke.py --transport socket \
        [--workers 2] [--requests 40] [--out-dir reports/distributed_smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.launch import serve  # noqa: E402

# Rollup keys that must match exactly across transports. Latency
# percentiles are excluded on purpose: routing time is wall-measured.
PARITY_KEYS = (
    "completed", "rejected", "expired", "per_member_counts",
    "per_member_spend", "total_spend", "generate_calls",
    "n_workers", "alive_workers", "reassigned", "router_versions",
    "per_worker_completed",
)
COORD_KEYS = ("syncs", "merged", "updates", "update_steps", "bursts",
              "stale_rejected", "leader_changes")


def run_serve(argv, label):
    t0 = time.time()
    print(f"--- {label}: serve {' '.join(argv)}", flush=True)
    summary = serve.main(argv)
    print(f"--- {label} done in {time.time() - t0:.1f}s", flush=True)
    return summary


def check(cond, what):
    if not cond:
        print(f"FAIL: {what}", flush=True)
        sys.exit(1)
    print(f"ok: {what}", flush=True)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--transport", choices=["local", "socket"],
                    default="socket",
                    help="socket also runs the local plane for the "
                         "parity check")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default="reports/distributed_smoke")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    base = [
        "--trace", "poisson", "--requests", str(args.requests),
        "--epochs", "4", "--seed", str(args.seed),
        "--workers", str(args.workers), "--online", "--cascade",
        "--sync-every", "0.02", "--budget", "0.02",
    ]

    local = run_serve(
        base + ["--trace-out",
                os.path.join(args.out_dir, "trace-local.json")],
        "local plane")
    check(local["completed"] == args.requests,
          f"local plane completed all {args.requests} requests")
    if args.transport == "local":
        with open(os.path.join(args.out_dir, "summary-local.json"),
                  "w") as f:
            json.dump(local, f, indent=2, default=str)
        print("distributed smoke (local only): PASS", flush=True)
        return

    sock = run_serve(
        base + ["--transport", "socket",
                "--trace-out",
                os.path.join(args.out_dir, "trace-socket.json")],
        "socket plane")

    # Real OS processes: controller + one per follower, all distinct.
    pids = sock.get("pids", {})
    check(len(set(pids.values())) >= args.workers
          and len(pids) == args.workers,
          f"socket run spanned {len(set(pids.values()))} distinct OS "
          f"processes {sorted(pids.values())}")
    check(pids.get(0) == os.getpid() or pids.get("0") == os.getpid(),
          "controller is this process (wid 0)")

    # Sharded pool layout covers every member with a valid owner.
    owners = sock.get("pool_owner", {})
    check(owners and all(0 <= int(o) < args.workers
                         for o in owners.values()),
          f"pool shard layout {owners}")

    # Transport parity: identical deterministic rollups.
    for key in PARITY_KEYS:
        lv, sv = local.get(key), sock.get(key)
        check(lv == sv, f"parity on {key!r}: local={lv} socket={sv}")
    for key in COORD_KEYS:
        lv = local["coordinator"].get(key)
        sv = sock["coordinator"].get(key)
        check(lv == sv,
              f"coordinator parity on {key!r}: local={lv} socket={sv}")
    versions = set(sock["router_versions"].values())
    check(len(versions) == 1,
          f"all workers converged to one router version {versions}")

    for name, summary in (("local", local), ("socket", sock)):
        with open(os.path.join(args.out_dir, f"summary-{name}.json"),
                  "w") as f:
            json.dump(summary, f, indent=2, default=str)
    for artifact in ("trace-local.json", "trace-socket.json"):
        check(os.path.exists(os.path.join(args.out_dir, artifact)),
              f"trace artifact {artifact} written")
    print("distributed smoke: PASS", flush=True)


if __name__ == "__main__":
    main()
