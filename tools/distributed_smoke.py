"""Distributed serving smoke: the socket transport against real processes.

Runs the seeded serve driver twice on the SAME argv — once on the
in-process ``LocalTransport`` plane, once on ``--transport socket``
(controller + N-1 follower OS processes, mesh-sharded pool, shared
ledger over ``LEDGER_OP``) — and asserts the message-passing refactor's
core contract:

  * **parity** — both planes converge to the same final router version
    on every worker and produce matching deterministic telemetry rollups
    (completed / spend / per-member counts / sync + merge + update
    counters). Only wall-measured latency percentiles may differ.
  * **real processes** — the socket run reports >= ``--workers`` distinct
    OS pids (the controller plus one per follower), proving the legs
    crossed process boundaries rather than a loopback.
  * **sharded pool** — the socket summary's member->owner layout covers
    every pool member, each owned by a valid worker.
  * **rpc observability** — both traces carry client/server ``rpc``
    spans joined by flow link ids; counts match across transports for
    the transport-invariant message kinds, the socket run's remote
    ``GENERATE`` legs all resolve to a server-side span in the follower
    process, and ``validate_span_tree`` is clean on both documents.
  * **federated metrics** — the socket run's merged fleet exposition
    (``--metrics-out`` + ``.fleet.prom``) carries follower-labelled
    series scraped over ``METRICS_REQ``.
  * **artifacts** — both summaries plus the controller's merged fleet
    trace (followers folded in via ``TRACE_REQ``) land in ``--out-dir``
    for CI upload.

    PYTHONPATH=src python tools/distributed_smoke.py --transport socket \
        [--workers 2] [--requests 40] [--out-dir reports/distributed_smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.launch import serve  # noqa: E402

# Rollup keys that must match exactly across transports. Latency
# percentiles are excluded on purpose: routing time is wall-measured.
PARITY_KEYS = (
    "completed", "rejected", "expired", "per_member_counts",
    "per_member_spend", "total_spend", "generate_calls",
    "n_workers", "alive_workers", "reassigned", "router_versions",
    "per_worker_completed",
)
COORD_KEYS = ("syncs", "merged", "updates", "update_steps", "bursts",
              "stale_rejected", "leader_changes")


def rpc_spans(doc):
    return [e for e in doc["traceEvents"]
            if e.get("ph") == "X" and e.get("name") == "rpc"]


def rpc_counts(doc, kinds):
    """(kind, side) -> span count, restricted to the given kinds."""
    counts = {}
    for e in rpc_spans(doc):
        a = e["args"]
        if a["kind"] in kinds:
            k = (a["kind"], a["side"])
            counts[k] = counts.get(k, 0) + 1
    return counts


def run_serve(argv, label):
    t0 = time.time()
    print(f"--- {label}: serve {' '.join(argv)}", flush=True)
    summary = serve.main(argv)
    print(f"--- {label} done in {time.time() - t0:.1f}s", flush=True)
    return summary


def check(cond, what):
    if not cond:
        print(f"FAIL: {what}", flush=True)
        sys.exit(1)
    print(f"ok: {what}", flush=True)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--transport", choices=["local", "socket"],
                    default="socket",
                    help="socket also runs the local plane for the "
                         "parity check")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default="reports/distributed_smoke")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    base = [
        "--trace", "poisson", "--requests", str(args.requests),
        "--epochs", "4", "--seed", str(args.seed),
        "--workers", str(args.workers), "--online", "--cascade",
        "--sync-every", "0.02", "--budget", "0.02",
    ]

    local = run_serve(
        base + ["--trace-out",
                os.path.join(args.out_dir, "trace-local.json")],
        "local plane")
    check(local["completed"] == args.requests,
          f"local plane completed all {args.requests} requests")
    if args.transport == "local":
        with open(os.path.join(args.out_dir, "summary-local.json"),
                  "w") as f:
            json.dump(local, f, indent=2, default=str)
        print("distributed smoke (local only): PASS", flush=True)
        return

    metrics_out = os.path.join(args.out_dir, "metrics-socket.prom")
    sock = run_serve(
        base + ["--transport", "socket",
                "--trace-out",
                os.path.join(args.out_dir, "trace-socket.json"),
                "--metrics-out", metrics_out],
        "socket plane")

    # Real OS processes: controller + one per follower, all distinct.
    pids = sock.get("pids", {})
    check(len(set(pids.values())) >= args.workers
          and len(pids) == args.workers,
          f"socket run spanned {len(set(pids.values()))} distinct OS "
          f"processes {sorted(pids.values())}")
    check(pids.get(0) == os.getpid() or pids.get("0") == os.getpid(),
          "controller is this process (wid 0)")

    # Sharded pool layout covers every member with a valid owner.
    owners = sock.get("pool_owner", {})
    check(owners and all(0 <= int(o) < args.workers
                         for o in owners.values()),
          f"pool shard layout {owners}")

    # Transport parity: identical deterministic rollups.
    for key in PARITY_KEYS:
        lv, sv = local.get(key), sock.get(key)
        check(lv == sv, f"parity on {key!r}: local={lv} socket={sv}")
    for key in COORD_KEYS:
        lv = local["coordinator"].get(key)
        sv = sock["coordinator"].get(key)
        check(lv == sv,
              f"coordinator parity on {key!r}: local={lv} socket={sv}")
    versions = set(sock["router_versions"].values())
    check(len(versions) == 1,
          f"all workers converged to one router version {versions}")

    # RPC observability: both traces validate (no dangling client->server
    # flow links), the transport-invariant message kinds emit identical
    # client/server span counts, and every remote GENERATE leg in the
    # socket trace resolves to a server-side span in the owning process.
    from repro.distributed import messages as M
    from repro.obs import validate_span_tree

    with open(os.path.join(args.out_dir, "trace-local.json")) as f:
        ldoc = json.load(f)
    with open(os.path.join(args.out_dir, "trace-socket.json")) as f:
        sdoc = json.load(f)
    for name, doc in (("local", ldoc), ("socket", sdoc)):
        errs = validate_span_tree(doc)
        check(not errs, f"{name} trace span tree valid "
                        f"({len(errs)} problems: {errs[:3]})")
    invariant = set(M.RPC_SPAN_KINDS) - {M.GENERATE, M.LEDGER_OP}
    lc, sc = rpc_counts(ldoc, invariant), rpc_counts(sdoc, invariant)
    check(lc and lc == sc,
          f"rpc span parity on transport-invariant kinds "
          f"({sum(lc.values())} spans over {len(lc)} (kind, side) pairs)")
    gen = [e for e in rpc_spans(sdoc) if e["args"]["kind"] == M.GENERATE]
    gen_cli = [e for e in gen if e["args"]["side"] == "client"]
    gen_srv = {e["args"]["rpc"]: e for e in gen
               if e["args"]["side"] == "server"}
    check(gen_cli, f"socket run produced remote GENERATE rpc spans "
                   f"({len(gen_cli)} client legs)")
    check(all(e["args"]["rpc"] in gen_srv
              and gen_srv[e["args"]["rpc"]]["pid"] != e["pid"]
              for e in gen_cli),
          "every remote GENERATE client span links to a server span in "
          "a different worker process")

    # Federated metrics: the merged fleet exposition carries follower-
    # labelled series next to the controller's own.
    fleet_path = metrics_out + ".fleet.prom"
    check(os.path.exists(fleet_path),
          f"fleet metrics exposition {fleet_path} written")
    with open(fleet_path) as f:
        fleet_text = f.read()
    check('worker="1"' in fleet_text,
          'fleet exposition contains follower-labelled (worker="1") series')
    check("rpc_requests" in fleet_text,
          "fleet exposition exports transport rpc telemetry")

    for name, summary in (("local", local), ("socket", sock)):
        with open(os.path.join(args.out_dir, f"summary-{name}.json"),
                  "w") as f:
            json.dump(summary, f, indent=2, default=str)
    for artifact in ("trace-local.json", "trace-socket.json"):
        check(os.path.exists(os.path.join(args.out_dir, artifact)),
              f"trace artifact {artifact} written")
    print("distributed smoke: PASS", flush=True)


if __name__ == "__main__":
    main()
