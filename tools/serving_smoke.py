"""Serving-runtime smoke: replay a ~2-virtual-second traffic trace through
the full queue -> scheduler -> engine pipeline and assert every request is
accounted for. Fast enough for tier-1-adjacent checks.

    PYTHONPATH=src python tools/serving_smoke.py
"""
from __future__ import annotations

import sys

from repro.launch.serve import build_routed_engine
from repro.serving import (
    BudgetGovernor,
    DONE,
    MicroBatchScheduler,
    SchedulerConfig,
    TraceConfig,
    default_service_model,
    make_trace,
)


def main() -> int:
    # Tiny everything: 2 cheapest members, a handful of training epochs
    # (the smoke exercises runtime mechanics, not router accuracy).
    engine, data, te = build_routed_engine(
        ["qwen3-0.6b", "granite-moe-1b-a400m"], seed=0, epochs=5,
        n_traffic=300)

    trace = make_trace(
        TraceConfig(kind="bursty", n_requests=24, rate=12.0, seed=0,
                    max_new=2, prompt_len_max=16, vocab=64),
        texts=[data.texts[i] for i in te],
    )  # rate 12/s -> ~2 virtual seconds of traffic
    governor = BudgetGovernor(budget=1e-3, window_s=0.5, lam0=1.0)
    sched = MicroBatchScheduler(
        engine, SchedulerConfig(score_batch=16, max_batch=8),
        governor=governor, service_time=default_service_model())
    summary = sched.run_trace(trace)

    n = summary["completed"] + summary["rejected"] + summary["expired"]
    ok = (n == len(trace)
          and summary["completed"] > 0
          and summary["total_spend"] > 0
          and all(r.output is not None for r in trace if r.status == DONE))
    print(sched.telemetry.report(summary.get("duration_s")))
    print(f"serving smoke: {'OK' if ok else 'FAIL'} "
          f"({summary['completed']}/{len(trace)} served, "
          f"spend ${summary['total_spend']:.6f}, "
          f"final lambda {governor.lam:.3g})")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
