"""Cascade smoke: one seeded escalate -> stop trace in under 5 seconds.

Exercises the real pieces end to end — deep-ensemble quality head
(``attn-ens``, bootstrap-trained), exact ``reg`` cost head, cost ladder,
:class:`CascadePolicy`, :class:`CascadeCoordinator`, and the micro-batching
scheduler's multi-leg lifecycle — against a stub pool (no LM generation):

  * EASY queries: the cheap member's answer is observed good -> stop at
    leg 1 (paying for the strong member there would be waste);
  * HARD queries: the cheap answer is observed inadequate and the
    ensemble predicts a strong upside -> escalate up the ladder, deliver
    the best answer, charge the SUM of leg costs.

The trace runs twice and must replay bit-identically (determinism).

    PYTHONPATH=src python tools/cascade_smoke.py
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.cascade import (
    CascadeConfig,
    CascadeCoordinator,
    CascadePolicy,
    cost_ladder,
)
from repro.core.predictors import PREDICTORS
from repro.core.router import PredictiveRouter
from repro.serving import (
    MicroBatchScheduler,
    Request,
    RoutedEngine,
    SchedulerConfig,
)
from repro.training import AdamConfig, adam_init, make_ensemble_predictor_step

DQ, SEED, LAM = 32, 0, 8.0
COST = np.array([0.2, 1.0, 3.0])          # member $ rates (the ladder)
QUAL_EASY = np.array([0.90, 0.92, 0.95])  # cheap is adequate
QUAL_HARD = np.array([0.15, 0.55, 0.92])  # only the strong rung delivers
N_REQ = 48


class StubMember:
    def __init__(self, name, cost_rate):
        self.name, self.cost_rate = name, cost_rate

    def generate(self, prompts, max_new=8, attn_mask=None):
        return np.zeros((len(prompts), max_new), np.int32)


def region_emb(rng, n, sign):
    mu = np.zeros(DQ, np.float32)
    mu[: DQ // 2] = 0.8 * sign
    e = rng.normal(0, 0.3, size=(n, DQ)).astype(np.float32) + mu
    return e / np.linalg.norm(e, axis=1, keepdims=True)


def build_engine(rng):
    """attn-ens quality head bootstrap-trained on both regions; exact reg
    cost head (constant member rates -> zero-weight head with rate bias)."""
    emb = np.concatenate([region_emb(rng, 128, +1.0),
                          region_emb(rng, 128, -1.0)])
    labels = np.concatenate([
        np.tile(QUAL_EASY, (128, 1)), np.tile(QUAL_HARD, (128, 1)),
    ]).astype(np.float32)
    labels += rng.normal(0, 0.03, labels.shape).astype(np.float32)
    # Distinct member embeddings: with near-identical rows the attention
    # context degenerates to a constant in q and the head cannot express
    # region-dependent quality at all.
    memb = rng.random((3, 4)).astype(np.float32)

    opt = AdamConfig(lr=5e-3)
    step = make_ensemble_predictor_step("attn-ens", opt)
    qp = PREDICTORS["attn-ens"].init(jax.random.key(SEED), DQ, 3,
                                     memb.shape[1])
    state = adam_init(opt, qp)
    boot = rng.poisson(1.0, size=(256, qp["bo"].shape[0])).astype(np.float32)
    for _ in range(200):
        _, qp, state = step(qp, state, emb, memb, labels, boot)

    # Exact cost path: a zero reg head + scaler mu = member rates means
    # denormalize_cost returns the rates verbatim (and the scaler is what
    # cost_ladder derives the escalation order from).
    cp = {"w": np.zeros((DQ, 3), np.float32), "b": np.zeros(3, np.float32)}
    router = PredictiveRouter(
        "attn-ens", "reg", qp, cp, memb, reward="R2",
        cost_scaler={"mu": np.asarray(COST, np.float64),
                     "sd": np.ones(3, np.float64)})
    pool = [StubMember(n, c) for n, c in
            zip(("cheap", "mid", "strong"), COST)]
    return RoutedEngine(router=router, pool=pool, lam=LAM)


def run_trace():
    rng = np.random.default_rng(SEED)
    engine = build_engine(rng)
    easy = region_emb(rng, N_REQ // 2, +1.0)
    hard = region_emb(rng, N_REQ // 2, -1.0)
    truth = {}

    # Requests alternate easy/hard; per-request truth keyed by text.
    ladder = cost_ladder(engine.router)
    reqs, embs = [], []
    for i in range(N_REQ):
        is_hard = i % 2 == 1
        e = hard[i // 2] if is_hard else easy[i // 2]
        text = f"{'hard' if is_hard else 'easy'}-{i}"
        truth[text] = QUAL_HARD if is_hard else QUAL_EASY
        r = Request(text=text, prompt=np.zeros(2, np.int32),
                    max_new=2, arrival_s=i * 1e-3)
        # Canonical cascade: every request starts at the cheapest rung and
        # buys stronger opinions only when the answer in hand is weak.
        r.forced_member = int(ladder[0])
        r.forced_member_name = engine.pool[int(ladder[0])].name
        reqs.append(r)
        embs.append(e)
    emb_of = {r.text: e for r, e in zip(reqs, embs)}
    engine.embed = lambda texts: np.stack([emb_of[t] for t in texts])

    coordinator = CascadeCoordinator(
        CascadePolicy(ladder, CascadeConfig(max_legs=3, beta=1.0)),
        observed_quality=lambda r: float(truth[r.text][r.member]))
    sched = MicroBatchScheduler(
        engine, SchedulerConfig(score_batch=16, max_batch=16),
        cascade=coordinator, service_time=lambda kind, n, wall: 1e-3)
    summary = sched.run_trace(reqs)
    return summary, coordinator, reqs


def main() -> int:
    t0 = time.perf_counter()
    s1, coord1, reqs1 = run_trace()
    wall = time.perf_counter() - t0
    s2, coord2, _ = run_trace()

    easy_reqs = [r for r in reqs1 if r.text.startswith("easy")]
    hard_reqs = [r for r in reqs1 if r.text.startswith("hard")]
    easy_one_leg = np.mean([r.leg == 1 for r in easy_reqs])
    hard_escalated = np.mean([r.leg > 1 for r in hard_reqs])
    cum_ok = all(abs(r.cum_cost - sum(r.leg_costs)) < 1e-12 for r in reqs1)
    hard_quality = np.mean([r.best_q for r in hard_reqs])

    checks = {
        "all requests finalized exactly once":
            s1["completed"] == N_REQ
            and s1["double_finalize_blocked"] == 0,
        "easy queries stop at leg 1": easy_one_leg >= 0.9,
        "hard queries escalate": hard_escalated >= 0.9,
        "escalation delivered the strong answer": hard_quality > 0.8,
        "cumulative cost = sum of leg costs": cum_ok,
        "escalations counted": s1["escalations"] == coord1.stats[
            "escalations"] > 0,
        "deterministic replay": (
            s1["escalations"] == s2["escalations"]
            and s1["finalized_by_leg"] == s2["finalized_by_leg"]
            and coord1.stats == coord2.stats),
        "trace under 5s": wall < 5.0,
    }
    for name, ok in checks.items():
        print(f"  [{'ok' if ok else 'FAIL'}] {name}")
    print(coord1.report())
    print(f"finalized by leg {s1['finalized_by_leg']}  "
          f"easy one-leg {easy_one_leg:.2f}  hard escalated "
          f"{hard_escalated:.2f}  hard best-q {hard_quality:.2f}  "
          f"wall {wall:.2f}s")
    ok = all(checks.values())
    print(f"cascade smoke: {'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
