"""Diff two bench-artifact directories and fail on regressions.

Each CI bench run archives ``BENCH_<suite>.json`` files (written by
``benchmarks.run``); this tool compares the new run against the previous
run's artifacts — the bench *trajectory* check that catches a perf slide
between PRs that no single run's absolute gates would:

    python tools/bench_diff.py reports/bench_prev reports/bench \\
        --max-regress 0.10

Failure conditions:

  * a suite that previously passed its gates now fails one (named);
  * a suite that previously ran clean now errors;
  * a directional headline metric regressed by more than ``--max-regress``
    (relative). Headlines declare their direction via
    ``benchmarks.common.headline(..., direction="lower"|"higher")``;
    undirected headlines are reported but never fail the diff.

Suites with no baseline artifact are reported as new and pass (the first
archived run seeds the trajectory). Stdlib-only: runs in CI without the
repo on PYTHONPATH.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Optional, Tuple


def load_reports(dirpath: str) -> Dict[str, dict]:
    out = {}
    for path in sorted(glob.glob(os.path.join(dirpath, "BENCH_*.json"))):
        name = os.path.basename(path)[len("BENCH_"):-len(".json")]
        try:
            with open(path) as f:
                out[name] = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: unreadable {path}: {e}")
    return out


def gate_map(report: dict) -> Dict[str, bool]:
    return {g["name"]: bool(g["passed"]) for g in report.get("gates", ())}


def headline_regression(prev: dict, new: dict,
                        max_regress: float) -> Optional[Tuple[str, float]]:
    """(description, relative regression) when the headline moved the wrong
    way by more than ``max_regress``; None otherwise."""
    hp, hn = prev.get("headline"), new.get("headline")
    if not hp or not hn or hp.get("metric") != hn.get("metric"):
        return None
    direction = hn.get("direction") or hp.get("direction")
    if direction not in ("higher", "lower"):
        return None
    pv, nv = hp.get("value"), hn.get("value")
    if not isinstance(pv, (int, float)) or not isinstance(nv, (int, float)) \
            or pv == 0:
        return None
    rel = (nv - pv) / abs(pv)
    regress = rel if direction == "lower" else -rel
    if regress > max_regress:
        return (f"{hn['metric']} {pv:g} -> {nv:g} "
                f"({regress * 100:+.1f}% worse, direction={direction})",
                regress)
    return None


def diff(prev_dir: str, new_dir: str, max_regress: float) -> List[str]:
    """Human-readable failure list ([] = trajectory clean)."""
    prev, new = load_reports(prev_dir), load_reports(new_dir)
    failures: List[str] = []
    if not new:
        return [f"no BENCH_*.json artifacts in {new_dir}"]
    for name, rn in sorted(new.items()):
        rp = prev.get(name)
        if rp is None:
            print(f"{name}: no baseline — seeding trajectory")
            continue
        if rn.get("error") and not rp.get("error"):
            failures.append(f"{name}: new error: {rn['error']}")
            continue
        gp, gn = gate_map(rp), gate_map(rn)
        for gname, passed in sorted(gn.items()):
            if not passed and gp.get(gname, False):
                failures.append(f"{name}: gate {gname} passed -> FAILED")
        hr = headline_regression(rp, rn, max_regress)
        if hr is not None:
            failures.append(f"{name}: headline regressed: {hr[0]}")
        else:
            hp, hn = rp.get("headline"), rn.get("headline")
            if hp and hn and hp.get("metric") == hn.get("metric"):
                print(f"{name}: {hn['metric']} {hp.get('value'):g} -> "
                      f"{hn.get('value'):g}")
    for name in sorted(set(prev) - set(new)):
        print(f"warning: suite {name} has a baseline but no new artifact")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("prev", help="baseline artifact dir (previous CI run)")
    ap.add_argument("new", help="this run's artifact dir")
    ap.add_argument("--max-regress", type=float, default=0.10,
                    help="max allowed relative regression on directional "
                         "headline metrics (default 0.10 = 10%%)")
    args = ap.parse_args()

    if not os.path.isdir(args.prev):
        # First run on a fresh cache: nothing to diff against.
        print(f"no baseline dir {args.prev} — seeding trajectory")
        return 0
    failures = diff(args.prev, args.new, args.max_regress)
    for f in failures:
        print(f"REGRESSION: {f}")
    if failures:
        return 1
    print("bench trajectory clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
