"""Online-adaptation smoke: one seeded drift -> adapt -> recover cycle.

Runs the full online loop (exploration choose, replay, drift alarm, burst
update, atomic router swap, detector recovery) against a stub pool and
synthetic embeddings — no LM generation, and the cost predictor is an
exact hand-built ``reg`` head (costs are constant per member), so the
whole cycle including JAX compilation lands under the 5-second budget.
The cycle runs twice and must replay bit-identically (determinism).

    PYTHONPATH=src python tools/online_smoke.py
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.core.predictors import PREDICTORS
from repro.core.router import PredictiveRouter
from repro.online import (
    DriftDetector,
    ExplorationConfig,
    OnlineAdapter,
    OnlineUpdateConfig,
)
from repro.serving import DONE, Request, RoutedEngine
from repro.training import AdamConfig, adam_init, make_predictor_step

DQ, SEED, LAM = 32, 0, 2.0
COST = np.array([0.2, 1.0])        # member $ rates
# Offline world: the pricey member earns its premium everywhere
# (R2: 0.85*exp(-0.5) = 0.52 > 0.45*exp(-0.1) = 0.41). Post-drift, region
# B's true pool strengths are reversed — the frozen beliefs misroute.
QUAL_A = np.array([0.45, 0.85])    # offline labels (both regions)
QUAL_B = np.array([0.80, 0.35])    # post-drift truth on region B
BATCH, N_A, N_B, N_RECOVER = 16, 6, 18, 6


class StubMember:
    def __init__(self, name, cost_rate):
        self.name, self.cost_rate = name, cost_rate


def region_emb(rng, n, sign):
    mu = np.zeros(DQ, np.float32)
    mu[: DQ // 2] = 0.8 * sign
    e = rng.normal(0, 0.4, size=(n, DQ)).astype(np.float32) + mu
    return e / np.linalg.norm(e, axis=1, keepdims=True)


def build_engine(rng):
    """Attn quality head trained on pre-drift labels; exact reg cost head.

    The offline corpus covers BOTH regions with pre-drift labels; the
    drift detector's reference is the region-A sample only (the pre-drift
    serving distribution, as a deployment would fit it).
    """
    emb = np.concatenate([region_emb(rng, 192, +1.0),
                          region_emb(rng, 64, -1.0)])
    quality = (np.tile(QUAL_A, (256, 1))
               + rng.normal(0, 0.05, (256, 2))).astype(np.float32)
    memb = np.stack([np.full(4, q) for q in QUAL_A]).astype(np.float32)

    opt = AdamConfig(lr=3e-3)
    step = make_predictor_step("attn", opt)
    qp = PREDICTORS["attn"].init(jax.random.key(SEED), DQ, 2, memb.shape[1])
    state = adam_init(opt, qp)
    for _ in range(30):
        _, qp, state = step(qp, state, emb, memb, quality)

    # Costs are constant per member: a zero-weight reg head with the rates
    # as bias predicts them exactly (nothing to train).
    cp = {"w": np.zeros((DQ, 2), np.float32),
          "b": np.asarray(COST, np.float32)}
    router = PredictiveRouter("attn", "reg", qp, cp, memb, reward="R2",
                              cost_scaler=None, centroids=emb[:4].copy())
    pool = [StubMember("cheap", COST[0]), StubMember("pricey", COST[1])]
    return RoutedEngine(router=router, pool=pool, lam=LAM), emb[:192]


def run_cycle():
    rng = np.random.default_rng(SEED)
    engine, ref_emb = build_engine(rng)
    truth = {}   # request id -> true quality row

    def feedback(req):
        return float(truth[req.rid][req.member])

    adapter = OnlineAdapter(
        engine, feedback,
        config=OnlineUpdateConfig(update_every=32, steps_per_update=8,
                                  burst_steps=32, batch_size=32,
                                  min_buffer=16),
        exploration=ExplorationConfig(epsilon=0.1, seed=SEED),
        drift=DriftDetector(window=32, threshold=3.0,
                            seed=SEED).fit(ref_emb,
                                           engine.router.centroids),
        seed=SEED,
    )

    phases = ["A"] * N_A + ["B"] * (N_B + N_RECOVER)
    mix, alarms_at = [], []
    now = 0.0
    for bi, phase in enumerate(phases):
        emb = region_emb(rng, BATCH, +1.0 if phase == "A" else -1.0)
        qual = QUAL_A if phase == "A" else QUAL_B
        s_hat, c_hat = engine.score_emb(emb)
        choices = adapter.choose(s_hat, c_hat, engine.lam, now)
        reqs = []
        for e, m in zip(emb, choices):
            r = Request(text="", prompt=np.zeros(1, np.int32))
            r.q_emb, r.member, r.status = e, int(m), DONE
            r.cost = float(COST[int(m)])
            truth[r.rid] = qual
            reqs.append(r)
        alarms_before = adapter.stats["drift_alarms"]
        adapter.observe(reqs, now)
        if adapter.stats["drift_alarms"] > alarms_before:
            alarms_at.append(bi)
        mix.append(float(np.mean(choices == 0)))   # fraction to cheap
        now += 0.1
    return adapter, mix, alarms_at


def main() -> int:
    t0 = time.perf_counter()
    ad1, mix1, alarms1 = run_cycle()
    cycle1_wall = time.perf_counter() - t0
    ad2, mix2, alarms2 = run_cycle()

    s = ad1.stats
    pre_b = np.mean(mix1[N_A: N_A + 2])                    # drift onset
    post_b = np.mean(mix1[-N_RECOVER:])                    # after adaptation
    recovered = (not alarms1
                 or max(alarms1) < len(mix1) - N_RECOVER)  # alarms stopped
    checks = {
        "drift alarm fired": s["drift_alarms"] >= 1,
        "burst update ran": s["bursts"] >= 1,
        "router republished": ad1.engine.router.version >= 2,
        "routing flipped to cheap on B": post_b > 0.8 >= 0.5 > pre_b,
        "detector recovered (alarms stopped)": recovered,
        "deterministic replay": (mix1 == mix2 and alarms1 == alarms2
                                 and ad1.stats == ad2.stats),
        "cycle under 5s": cycle1_wall < 5.0,
    }
    for name, ok in checks.items():
        print(f"  [{'ok' if ok else 'FAIL'}] {name}")
    print(ad1.report())
    print(f"cheap-member share: pre-drift-adapt {pre_b:.2f} -> "
          f"post {post_b:.2f}; alarms at batches {alarms1}; "
          f"cycle wall {cycle1_wall:.2f}s")
    ok = all(checks.values())
    print(f"online smoke: {'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
