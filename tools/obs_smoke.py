"""Observability smoke: seeded traced cascade run, replayed bit-identically,
in under 5 seconds.

Exercises the whole observability plane against the stub cascade scenario
(no LM generation, no training beyond the bootstrap head): a
:class:`~repro.obs.TraceRecorder` threaded through the micro-batching
scheduler + cascade coordinator, a :class:`~repro.obs.MetricsRegistry`
with the full scheduler/cascade metric set, and the Chrome-trace exporter.

Checks:
  * the exported trace is schema-valid Chrome JSON and its span tree is
    well-formed — every request covered admission -> legs -> finalize,
    legs nested inside their request root, no overlapping legs;
  * every cascade leg span links (via its ``gen`` arg) to the generate
    micro-batch span that actually served it;
  * the run replays bit-identically: trace JSON and deterministic metrics
    snapshot are byte-equal across two fresh runs (virtual-clock
    timestamps and admission-order trace keys, no wall time anywhere);
  * the same run in **streaming mode** (sampling 0.25 + per-worker cap +
    rotating segment flushes) concatenates back into a valid trace that
    retains 100%% of the escalated request trees, bounds the recorder's
    peak buffer, and is segment-for-segment byte-identical across
    replays;
  * artifacts land on disk for CI upload (--out-dir).

    PYTHONPATH=src python tools/obs_smoke.py [--out-dir reports/obs_smoke]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import numpy as np

from repro.cascade import (
    CascadeConfig,
    CascadeCoordinator,
    CascadePolicy,
    cost_ladder,
)
from repro.core.predictors import PREDICTORS
from repro.core.router import PredictiveRouter
from repro.obs import (
    MetricsRegistry,
    ObsFlusher,
    TraceRecorder,
    TraceSampler,
    concat_dir,
    register_scheduler_metrics,
    request_trees,
    trace_summary,
    validate_chrome_trace,
    validate_span_tree,
)
from repro.obs.trace import trace_doc_to_json
from repro.serving import (
    MicroBatchScheduler,
    Request,
    RoutedEngine,
    SchedulerConfig,
)
from repro.training import AdamConfig, adam_init, make_ensemble_predictor_step

DQ, SEED, LAM = 32, 0, 8.0
COST = np.array([0.2, 1.0, 3.0])
QUAL_EASY = np.array([0.90, 0.92, 0.95])
QUAL_HARD = np.array([0.15, 0.55, 0.92])
N_REQ = 48


class StubMember:
    def __init__(self, name, cost_rate):
        self.name, self.cost_rate = name, cost_rate

    def generate(self, prompts, max_new=8, attn_mask=None):
        return np.zeros((len(prompts), max_new), np.int32)


def region_emb(rng, n, sign):
    mu = np.zeros(DQ, np.float32)
    mu[: DQ // 2] = 0.8 * sign
    e = rng.normal(0, 0.3, size=(n, DQ)).astype(np.float32) + mu
    return e / np.linalg.norm(e, axis=1, keepdims=True)


def build_engine(rng):
    emb = np.concatenate([region_emb(rng, 128, +1.0),
                          region_emb(rng, 128, -1.0)])
    labels = np.concatenate([
        np.tile(QUAL_EASY, (128, 1)), np.tile(QUAL_HARD, (128, 1)),
    ]).astype(np.float32)
    labels += rng.normal(0, 0.03, labels.shape).astype(np.float32)
    memb = rng.random((3, 4)).astype(np.float32)

    opt = AdamConfig(lr=5e-3)
    step = make_ensemble_predictor_step("attn-ens", opt)
    qp = PREDICTORS["attn-ens"].init(jax.random.key(SEED), DQ, 3,
                                     memb.shape[1])
    state = adam_init(opt, qp)
    boot = rng.poisson(1.0, size=(256, qp["bo"].shape[0])).astype(np.float32)
    for _ in range(120):
        _, qp, state = step(qp, state, emb, memb, labels, boot)

    cp = {"w": np.zeros((DQ, 3), np.float32), "b": np.zeros(3, np.float32)}
    router = PredictiveRouter(
        "attn-ens", "reg", qp, cp, memb, reward="R2",
        cost_scaler={"mu": np.asarray(COST, np.float64),
                     "sd": np.ones(3, np.float64)})
    pool = [StubMember(n, c) for n, c in
            zip(("cheap", "mid", "strong"), COST)]
    return RoutedEngine(router=router, pool=pool, lam=LAM)


STREAM_RATE, STREAM_CAP, SCRAPE_S = 0.25, 4096, 2e-3


def run_traced(stream_dir=None):
    """One seeded cascade run under the recorder; returns artifacts.

    With ``stream_dir`` set the run uses the full streaming stack —
    deterministic head+tail sampling (rate ``STREAM_RATE``, head=0 so
    sampling actually bites), a per-worker buffered-event cap, and
    rotating segment flushes every ``SCRAPE_S`` virtual seconds — and the
    returned trace JSON is the canonical concatenation of the segments.
    """
    rng = np.random.default_rng(SEED)
    engine = build_engine(rng)
    easy = region_emb(rng, N_REQ // 2, +1.0)
    hard = region_emb(rng, N_REQ // 2, -1.0)
    truth = {}

    ladder = cost_ladder(engine.router)
    reqs, embs = [], []
    for i in range(N_REQ):
        is_hard = i % 2 == 1
        e = hard[i // 2] if is_hard else easy[i // 2]
        text = f"{'hard' if is_hard else 'easy'}-{i}"
        truth[text] = QUAL_HARD if is_hard else QUAL_EASY
        r = Request(text=text, prompt=np.zeros(2, np.int32),
                    max_new=2, arrival_s=i * 1e-3)
        r.forced_member = int(ladder[0])
        r.forced_member_name = engine.pool[int(ladder[0])].name
        reqs.append(r)
        embs.append(e)
    emb_of = {r.text: e for r, e in zip(reqs, embs)}
    engine.embed = lambda texts: np.stack([emb_of[t] for t in texts])

    label = f"obs-smoke-seed{SEED}"
    if stream_dir is None:
        recorder, flusher = TraceRecorder(label=label), None
    else:
        recorder = TraceRecorder(
            label=label, sampler=TraceSampler(STREAM_RATE, seed=SEED, head=0),
            max_buffered_per_worker=STREAM_CAP)
        flusher = ObsFlusher(stream_dir, recorder=recorder,
                             scrape_every_s=SCRAPE_S, label=label)
    registry = MetricsRegistry()
    coordinator = CascadeCoordinator(
        CascadePolicy(ladder, CascadeConfig(max_legs=3, beta=1.0)),
        observed_quality=lambda r: float(truth[r.text][r.member]))
    sched = MicroBatchScheduler(
        engine, SchedulerConfig(score_batch=16, max_batch=16),
        cascade=coordinator, service_time=lambda kind, n, wall: 1e-3,
        tracer=recorder.scoped(0), flusher=flusher)
    register_scheduler_metrics(registry, sched)
    summary = sched.run_trace(reqs)
    if flusher is not None:
        flusher.finalize(sched.clock.now)
        trace_json = trace_doc_to_json(concat_dir(stream_dir))
    else:
        trace_json = recorder.to_json()
    return trace_json, registry.to_json(deterministic=True), summary, recorder


def run_rescue():
    """Deadline-pressure variant: requests whose deadlines fire mid-cascade
    while they hold a best-so-far answer are *rescued* (finalized done with
    the answer in hand), requests that expire empty-handed stay expired —
    and the trace must tell the same story as the queue counters: a rescued
    tree carries a ``rescued`` instant and a done root, never an ``expire``
    instant, and the ``expire`` instants in the trace match ``queue.expired``
    exactly."""
    rng = np.random.default_rng(SEED)
    engine = build_engine(rng)
    easy = region_emb(rng, N_REQ // 2, +1.0)
    hard = region_emb(rng, N_REQ // 2, -1.0)
    truth = {}
    ladder = cost_ladder(engine.router)
    reqs, embs = [], []
    for i in range(N_REQ):
        is_hard = i % 2 == 1
        e = hard[i // 2] if is_hard else easy[i // 2]
        text = f"{'hard' if is_hard else 'easy'}-{i}"
        truth[text] = QUAL_HARD if is_hard else QUAL_EASY
        r = Request(text=text, prompt=np.zeros(2, np.int32),
                    max_new=2, arrival_s=i * 1e-3,
                    deadline_s=i * 1e-3 + 4e-3)
        r.forced_member = int(ladder[0])
        r.forced_member_name = engine.pool[int(ladder[0])].name
        reqs.append(r)
        embs.append(e)
    emb_of = {r.text: e for r, e in zip(reqs, embs)}
    engine.embed = lambda texts: np.stack([emb_of[t] for t in texts])
    recorder = TraceRecorder(label="obs-smoke-rescue")
    coordinator = CascadeCoordinator(
        CascadePolicy(ladder, CascadeConfig(max_legs=3, beta=1.0)),
        observed_quality=lambda r: float(truth[r.text][r.member]))
    sched = MicroBatchScheduler(
        engine, SchedulerConfig(score_batch=16, max_batch=16),
        cascade=coordinator, service_time=lambda kind, n, wall: 1e-3,
        tracer=recorder.scoped(0))
    summary = sched.run_trace(reqs)
    return recorder.to_json(), summary, sched


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out-dir", default="reports/obs_smoke",
                    help="artifact directory for the trace + metrics JSON")
    args = ap.parse_args()

    t0 = time.perf_counter()
    trace1, metrics1, s1, _ = run_traced()
    wall = time.perf_counter() - t0
    trace2, metrics2, _, _ = run_traced()

    import json
    doc = json.loads(trace1)
    schema_errors = validate_chrome_trace(doc)
    tree_errors = validate_span_tree(doc)
    summ = trace_summary(doc)
    trees = request_trees(doc)
    covered = all(
        t["root"] is not None
        and any(e["name"] == "leg" for e in t["events"])
        and len(t["admits"]) >= 1
        for t in trees.values())
    legs = [e for t in trees.values() for e in t["legs"]]
    linked = legs and all("gen" in (e.get("args") or {}) for e in legs)

    # Deadline-rescue mode: the span tree must agree with the queue
    # counters about who was rescued (done, answer in hand) vs expired.
    r_trace, r_sum, r_sched = run_rescue()
    rdoc = json.loads(r_trace)
    r_tree_errors = validate_span_tree(rdoc)
    r_trees = request_trees(rdoc)
    n_rescued = n_expire_inst = 0
    rescue_consistent = True
    for t in r_trees.values():
        names = [e["name"] for e in t["events"]]
        root_args = ((t["root"] or {}).get("args") or {})
        n_rescued += names.count("rescued")
        n_expire_inst += names.count("expire")
        if "rescued" in names:
            # A rescued request finalizes done on its best-so-far answer;
            # an expire instant in the same tree would contradict it.
            rescue_consistent &= ("expire" not in names
                                  and root_args.get("status") == "done"
                                  and root_args.get("rescued") is True)
        elif "expire" in names:
            rescue_consistent &= root_args.get("status") == "expired"

    # Streaming mode: same seeded scenario through sampling + cap +
    # rotating flushes, twice, into sibling segment dirs.
    sdir1 = os.path.join(args.out_dir, "stream")
    sdir2 = os.path.join(args.out_dir, "stream_replay")
    st1, _, ss1, srec = run_traced(stream_dir=sdir1)
    st2, _, _, _ = run_traced(stream_dir=sdir2)
    sdoc = json.loads(st1)
    s_schema = validate_chrome_trace(sdoc)
    s_tree = validate_span_tree(sdoc)
    s_trees = request_trees(sdoc)
    # Escalated trees are anomalous (readmit instants): 100% retained.
    readmits = sum(1 for t in s_trees.values() for e in t["events"]
                   if e["name"] == "readmit")
    n_kept = len(s_trees)
    seg_identical = (
        sorted(os.listdir(sdir1)) == sorted(os.listdir(sdir2))
        and all(open(os.path.join(sdir1, n), "rb").read()
                == open(os.path.join(sdir2, n), "rb").read()
                for n in os.listdir(sdir1)))

    os.makedirs(args.out_dir, exist_ok=True)
    with open(os.path.join(args.out_dir, "trace.json"), "w") as f:
        f.write(trace1)
    with open(os.path.join(args.out_dir, "metrics.json"), "w") as f:
        f.write(metrics1)
    with open(os.path.join(args.out_dir, "stream_trace.json"), "w") as f:
        f.write(st1)

    checks = {
        "schema-valid chrome trace": not schema_errors,
        "well-formed span tree": not tree_errors,
        "every request admission->legs->finalize":
            covered and summ["finalized"] == N_REQ
            and s1["completed"] == N_REQ,
        "cascade decisions traced":
            summ["by_name"].get("cascade_decision", 0) >= N_REQ,
        "legs link their generate micro-batch span": bool(linked),
        "rescue trees consistent (rescued != expired)":
            n_rescued >= 1 and rescue_consistent and not r_tree_errors
            and n_expire_inst == r_sched.queue.expired,
        "replay bit-identity (trace)": trace1 == trace2,
        "replay bit-identity (metrics)": metrics1 == metrics2,
        "streaming concat schema+tree valid": not (s_schema or s_tree),
        "streaming retains all escalated trees":
            ss1["escalations"] > 0 and readmits == ss1["escalations"],
        "streaming samples out non-anomalous trees":
            0 < n_kept < N_REQ
            and srec.stats["requests_sampled_out"] > 0,
        "streaming recorder peak under cap":
            srec.peak_buffered < STREAM_CAP
            and srec.peak_buffered < summ["events"],
        "streaming replay segment byte-identity":
            seg_identical and st1 == st2,
        "trace under 5s": wall < 5.0,
    }
    for name, ok in checks.items():
        print(f"  [{'ok' if ok else 'FAIL'}] {name}")
    for err in (schema_errors + tree_errors + s_schema + s_tree)[:8]:
        print(f"    error: {err}")
    print(f"{summ['events']} events  {summ['requests']} requests  "
          f"escalations {s1['escalations']}  wall {wall:.2f}s  "
          f"artifacts -> {args.out_dir}/")
    print(f"streaming: {len(os.listdir(sdir1))} segment files  "
          f"{n_kept}/{N_REQ} trees kept  peak buffered "
          f"{srec.peak_buffered}  drops {srec.drop_stats}")
    ok = all(checks.values())
    print(f"obs smoke: {'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
